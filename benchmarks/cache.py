"""Fig. 10 reproduction: local database cache capacity vs communication.

Remote (cache-miss) queries and hit rate as the cache capacity grows,
relative to the data graph size."""

from __future__ import annotations

from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.ref_engine import GraphDB, RefEngine
from repro.graph.generate import powerlaw

from .common import Table


def run() -> Table:
    g = powerlaw(400, 4, seed=2)
    t = Table("Fig. 10: DB cache capacity vs remote queries",
              ["pattern", "capacity %", "remote rows", "hit rate %"])
    for pname in ("q2", "q4"):
        p = get_pattern(pname)
        plan = generate_best_plan(p, g.stats())
        for frac in (0.01, 0.05, 0.2, 1.0):
            db = GraphDB(g, cache_capacity=max(1, int(g.n * frac)))
            eng = RefEngine(plan, p, g, db=db)
            eng.run()
            t.add(pname, f"{frac * 100:.0f}", db.remote_queries,
                  f"{db.hit_rate * 100:.1f}")
    return t


if __name__ == "__main__":
    run().show()
