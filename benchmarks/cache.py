"""Fig. 10 reproduction: local database cache capacity vs communication.

Two sweeps, same axes (capacity relative to the data graph, remote rows,
hit rate):

* the paper-faithful sweep — the ``RefEngine`` interpreter with the
  per-task LRU ``GraphDB`` cache (the original Fig. 10 measurement);
* the **device cache** sweep — the real vectorized engines through the
  out-of-core fetch path (``oocache``: host-RAM row shards + bounded
  device cache + async prefetch), reporting cold rows, hit rate, and
  bytes moved per DBQ level, with the fully-resident ``jax`` engine as
  the 100%-capacity baseline.

Usage::

    PYTHONPATH=src python -m benchmarks.cache [--smoke] [--json PATH]

The ``BENCH_cache.json`` artifact lands in the repo root by default (it
is committed with each PR so the perf trajectory is tracked in-repo; CI
also uploads it); ``--json`` redirects it. ``--smoke`` shrinks the graph
so the sweep fits the CI budget.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.executor import make_executor
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.ref_engine import GraphDB, RefEngine
from repro.graph.generate import powerlaw

from .common import Table


def run(n: int = 400) -> Table:
    g = powerlaw(n, 4, seed=2)
    t = Table("Fig. 10: DB cache capacity vs remote queries (interpreter)",
              ["pattern", "capacity %", "remote rows", "hit rate %"])
    for pname in ("q2", "q4"):
        p = get_pattern(pname)
        plan = generate_best_plan(p, g.stats())
        for frac in (0.01, 0.05, 0.2, 1.0):
            db = GraphDB(g, cache_capacity=max(1, int(g.n * frac)))
            eng = RefEngine(plan, p, g, db=db)
            eng.run()
            t.add(pname, f"{frac * 100:.0f}", db.remote_queries,
                  f"{db.hit_rate * 100:.1f}")
    return t


def run_device_cache(n: int = 400, fracs=(0.02, 0.05, 0.10, 0.24),
                     batch: int = 64) -> (Table, List[Dict]):
    """Capacity % (device-resident rows / N) vs cold rows + hit rate for
    the vectorized engines; the resident ``jax`` engine anchors 100%."""
    g = powerlaw(n, 4, seed=2)
    t = Table("Device row cache: capacity vs cold rows (vectorized engines)",
              ["pattern", "engine", "capacity %", "count", "cold rows",
               "hit rate %", "moved MB", "prefetch rows"])
    records: List[Dict] = []
    # the resident engine's true row bytes: DeviceGraph pads the width
    # with lane=128, so the baseline transfer is (N+1) rows x that width
    # — comparable with the oocache byte counts
    from repro.graph.storage import padded_width
    d_row = padded_width(int(g.deg.max()), lane=128) * 4  # bytes per row
    for pname in ("q2", "q4"):
        p = get_pattern(pname)
        plan = generate_best_plan(p, g.stats())
        jx = make_executor("jax").run(plan, g, batch=batch)
        t.add(pname, "jax", "100 (resident)", jx.count, g.n + 1, "-",
              f"{(g.n + 1) * d_row / 1e6:.2f}", 0)
        records.append(dict(pattern=pname, engine="jax", capacity_frac=1.0,
                            count=int(jx.count), cold_rows=g.n + 1,
                            hit_rate=None, per_level=None))
        for frac in fracs:
            cap = max(1, int(g.n * frac * 0.75))
            hot = max(1, int(g.n * frac * 0.25))
            st = make_executor("oocache", cache_rows=cap, hot=hot).run(
                plan, g, batch=batch)
            assert st.count == jx.count, (pname, frac, st.count, jx.count)
            c = st.extras["cache"]
            resid = st.extras["device_resident_rows"]
            t.add(pname, "oocache", f"{resid / (g.n + 1) * 100:.0f}",
                  st.count, c["cold_rows"], f"{c['hit_rate'] * 100:.1f}",
                  f"{c['bytes_moved'] / 1e6:.2f}", c["prefetch_rows"])
            records.append(dict(
                pattern=pname, engine="oocache",
                capacity_frac=resid / (g.n + 1), count=int(st.count),
                cold_rows=c["cold_rows"], hit_rate=c["hit_rate"],
                bytes_moved=c["bytes_moved"],
                bytes_demand=c["bytes_demand"],
                bytes_prefetch=c["bytes_prefetch"],
                prefetch_used=c["prefetch_used"],
                per_level={str(k): v for k, v in c["per_level"].items()}))
    return t, records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph + short sweep (CI budget)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_cache.json artifact here "
                         "(default: the repo root)")
    args = ap.parse_args()
    n = 150 if args.smoke else 400
    fracs = (0.05, 0.20) if args.smoke else (0.02, 0.05, 0.10, 0.24)
    t1 = run(n)
    t1.show()
    t2, records = run_device_cache(n, fracs=fracs)
    t2.show()
    path = args.json or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_cache.json")
    payload = dict(
        benchmark="cache",
        figure="Fig. 10 + device-cache sweep",
        graph=dict(kind="powerlaw", n=n, m_per_node=4, seed=2),
        records=records)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {path} ({len(records)} records)")


if __name__ == "__main__":
    main()
