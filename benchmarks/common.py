"""Shared benchmark helpers: timing + table output."""

from __future__ import annotations

import time
from typing import Callable, List, Sequence


def timeit(fn: Callable, repeat: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


class Table:
    def __init__(self, title: str, columns: Sequence[str]):
        self.title = title
        self.columns = list(columns)
        self.rows: List[list] = []

    def add(self, *row):
        self.rows.append(list(row))

    def show(self) -> None:
        print(f"\n## {self.title}")
        widths = [max(len(str(c)), *(len(str(r[i])) for r in self.rows))
                  if self.rows else len(str(c))
                  for i, c in enumerate(self.columns)]
        print("  ".join(str(c).ljust(w)
                        for c, w in zip(self.columns, widths)))
        for r in self.rows:
            print("  ".join(str(x).ljust(w) for x, w in zip(r, widths)))
