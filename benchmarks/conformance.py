"""Cross-engine conformance: every backend of the unified Executor API
must produce identical match counts on the same plan (the correctness bar
set by the distributed-subgraph-matching survey — exact agreement, not
approximate). The driver's splitting/overflow policy is shared, so any
disagreement is an engine bug, never a chunking artifact."""

from __future__ import annotations

from repro.core.executor import make_executor
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.graph.generate import powerlaw

from .common import Table

PATTERNS = ("triangle", "square", "clique4", "house")


def run() -> Table:
    g = powerlaw(150, 4, seed=7)
    t = Table("Cross-engine conformance (unified Executor API)",
              ["pattern", "ref", "jax", "jax-gpu", "oocache", "ooc hit %",
               "agree"])
    for pname in PATTERNS:
        p = get_pattern(pname)
        plan = generate_best_plan(p, g.stats())
        ref = make_executor("ref").run(plan, g, batch=64)
        jx = make_executor("jax").run(plan, g, batch=64)
        # fused gather+intersect fetch path, Pallas kernel in interpret
        # mode so the real kernel code runs on this CPU container
        gpu = make_executor("jax-gpu",
                            gather_intersect_impl="interpret").run(
                                plan, g, batch=64)
        # whole device footprint (slab + staging + hot + sentinel)
        # bounded below 25% of the graph's rows, like the tests
        ooc = make_executor("oocache", cache_rows=int(g.n * 0.12),
                            hot=int(g.n * 0.04)).run(plan, g, batch=64)
        agree = ref.count == jx.count == gpu.count == ooc.count
        t.add(pname, ref.count, jx.count, gpu.count, ooc.count,
              f"{ooc.extras['cache']['hit_rate'] * 100:.1f}",
              "yes" if agree else "NO")
    return t


if __name__ == "__main__":
    run().show()
