"""Fig. 9 reproduction: effect of each execution-plan optimization.

Raw plan -> +CSE -> +reordering -> +triangle cache, measured as executed
INT/DBQ instruction counts (the paper's cost units) on real graphs."""

from __future__ import annotations

from repro.core.pattern import get_pattern
from repro.core.plangen import (generate_optimized_plan, generate_raw_plan,
                                search_matching_orders)
from repro.core.ref_engine import RefEngine
from repro.graph.generate import powerlaw

from .common import Table


def run() -> Table:
    g = powerlaw(300, 4, seed=1)
    t = Table("Fig. 9: plan optimizations (executed instruction counts)",
              ["pattern", "variant", "INT+TRC", "DBQ", "TRC hits",
               "matches"])
    for pname in ("q2", "q4", "fan5"):
        p = get_pattern(pname)
        order = search_matching_orders(p, g.stats()).candidates[0]
        variants = [
            ("raw", dict(use_cse=False, use_reorder=False, use_trc=False)),
            ("+cse", dict(use_cse=True, use_reorder=False, use_trc=False)),
            ("+reorder", dict(use_cse=True, use_reorder=True,
                              use_trc=False)),
            ("+trc", dict(use_cse=True, use_reorder=True, use_trc=True)),
        ]
        for name, kw in variants:
            plan = generate_optimized_plan(p, order, **kw)
            eng = RefEngine(plan, p, g)
            eng.run()
            c = eng.counters
            t.add(pname, name, c.computation_cost, c.dbq, c.trc_hits,
                  c.matches)
    return t


if __name__ == "__main__":
    run().show()
