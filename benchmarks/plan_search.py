"""Table 4 reproduction: best-execution-plan search efficiency.

Random connected ER patterns per vertex count n; report the proportion of
matching orders surviving the two pruning techniques and the wall time of
best-plan generation (BENU and S-BENU)."""

from __future__ import annotations

import itertools
import math
import time

import numpy as np

from repro.core.estimate import GraphStats
from repro.core.pattern import Pattern
from repro.core.plangen import generate_best_plan, search_matching_orders
from repro.core.sbenu import generate_best_sbenu_plans

from .common import Table


def random_connected(n: int, extra: int, rng, directed=False) -> Pattern:
    perm = rng.permutation(n)
    edges = {(min(int(perm[i]), int(perm[i + 1])),
              max(int(perm[i]), int(perm[i + 1])))
             for i in range(n - 1)}
    all_e = [e for e in itertools.combinations(range(n), 2)
             if e not in edges]
    if all_e and extra:
        idx = rng.choice(len(all_e), size=min(extra, len(all_e)),
                         replace=False)
        edges |= {all_e[i] for i in idx}
    if directed:
        es = []
        for a, b in sorted(edges):
            es.append((a, b) if rng.random() < 0.5 else (b, a))
        return Pattern(n, tuple(es), directed=True, name=f"er{n}")
    return Pattern(n, tuple(sorted(edges)), name=f"er{n}")


def run(n_patterns: int = 8, n_range=(4, 5, 6, 7)) -> Table:
    stats = GraphStats(1_000_000, 10_000_000, delta_edges=1000)
    t = Table("Table 4: best execution plan search",
              ["n", "BENU prop %", "BENU time (s)",
               "S-BENU prop %", "S-BENU time (s)"])
    rng = np.random.default_rng(0)
    for n in n_range:
        props_b, times_b, props_s, times_s = [], [], [], []
        for i in range(n_patterns):
            p = random_connected(n, extra=int(rng.integers(0, n)), rng=rng)
            t0 = time.perf_counter()
            sr = search_matching_orders(p, stats)
            generate_best_plan(p, stats)
            times_b.append(time.perf_counter() - t0)
            props_b.append(100.0 * sr.orders_explored / sr.orders_total)
            dp = random_connected(n, extra=int(rng.integers(0, n)),
                                  rng=rng, directed=True)
            t0 = time.perf_counter()
            generate_best_sbenu_plans(dp, stats)
            times_s.append(time.perf_counter() - t0)
            # proportion across all delta plans
            tot = expl = 0
            from repro.core.sbenu import incremental_patterns
            for ip in incremental_patterns(dp):
                sr2 = search_matching_orders(
                    dp, stats, fixed_prefix=(ip.delta_src, ip.delta_dst),
                    delta_edge=ip.delta_edge, se_classes=ip.se_classes())
                tot += sr2.orders_total
                expl += sr2.orders_explored
            props_s.append(100.0 * expl / max(tot, 1))
        t.add(n, f"{np.mean(props_b):.1f}", f"{np.mean(times_b):.3f}",
              f"{np.mean(props_s):.1f}", f"{np.mean(times_s):.3f}")
    return t


if __name__ == "__main__":
    run().show()
