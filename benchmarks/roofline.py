"""§Roofline tables.

Two modes:

* default — reads the dry-run JSONs and prints the three roofline terms
  per (arch x shape x mesh), the dominant bottleneck, and useful-FLOP
  ratios (the original transformer-cell table);
* ``--fused`` — the GPU fetch path's bytes model: for each pattern, run
  the unfused ``jax`` engine through the unified Executor and report
  **achieved vs lane-math bytes moved per DBQ level** for both fetch
  paths, plus an exactness gate that runs the fused ``jax-gpu`` engine
  (Pallas kernel in interpret mode on this CPU container) on a small
  clipped-caps configuration and asserts agreement — the Pallas
  interpreter traces its grid step by step, so the gate stays small
  while the bytes table prices the full run. "Achieved" prices the
  measured frontier occupancy (the level sizes the backend accumulates);
  "lane-math" prices the dense capacity bound every chunk pays shape-wise.
  The fused path drops the materialize+re-read round trip of every
  single-use DBQ row set (``engine_jax.classify_fusable_dbqs`` — the same
  classification the engine executes, so the model and the program
  agree): 3x row bytes -> 1x on fusable levels. Writes
  ``BENCH_gpu_fetch.json`` (the CI artifact, committed into the repo root
  like the other BENCH files). Wall times are CPU/interpret-mode numbers
  — the bytes columns, not the seconds, are the accelerator claim.

    PYTHONPATH=src python -m benchmarks.roofline --fused \
        [--n 400 --deg 4 --batch 64] [--json BENCH_gpu_fetch.json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

try:
    from .common import Table
except ImportError:                      # run as a script
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import Table

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FUSED_PATTERNS = ("triangle", "square", "clique4", "house")


def run(result_dir: str = None) -> Table:
    dirs = ([result_dir] if result_dir else
            [os.path.join(ROOT, "results", d)
             for d in ("dryrun", "dryrun_final_multipod", "dryrun_opt",
                       "dryrun_opt2")])
    t = Table("Roofline terms per cell (per-chip seconds; v5e constants)",
              ["cell", "mesh", "variant", "mem GiB/dev", "compute ms",
               "memory ms", "collective ms", "dominant", "useful-FLOP %"])
    any_files = False
    for d in dirs:
        variant = ("optimized" if "opt" in os.path.basename(d)
                   else "baseline")
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            any_files = True
            with open(f) as fh:
                r = json.load(fh)
            ro = r["roofline"]
            t.add(f"{r['arch']}:{r['shape']}",
                  "2pod" if "pod,data" in r["mesh"] else "1pod",
                  variant if variant == "baseline"
                  else f"opt:{r.get('sharding_mode', '-')}",
                  f"{r['memory_analysis']['peak_bytes_per_device'] / 2**30:.2f}",
                  f"{ro['compute_s'] * 1e3:.2f}",
                  f"{ro['memory_s'] * 1e3:.1f}",
                  f"{ro['collective_s'] * 1e3:.2f}",
                  ro["dominant"],
                  f"{ro['useful_flops_ratio'] * 100:.0f}"
                  if ro["useful_flops_ratio"] else "-")
    if not any_files:
        print(f"(no dry-run results under {dirs}; run "
              "`python -m repro.launch.dryrun --all` first)")
    return t


def _dbq_levels(plan):
    """(dbq target, enu level index) per DBQ: level l means the DBQ reads
    the frontier produced by the l-th ENU (-1 = the start batch)."""
    out, level = [], -1
    for ins in plan.instrs:
        if ins.op == "DBQ":
            out.append((ins.target, level))
        elif ins.op == "ENU":
            level += 1
    return out


def run_fused(args) -> Table:
    # the benchmark owns its config: ambient kernel toggles (e.g. the CI
    # matrix cell's REPRO_INTERSECT_IMPL=pallas-interpret) would route
    # the full-size baseline through the Pallas interpreter (~20x wall
    # clock) and corrupt the committed times — clear them for the run
    saved = {var: os.environ.pop(var, None)
             for var in ("REPRO_INTERSECT_IMPL", "REPRO_FUSED_FETCH",
                         "REPRO_GATHER_INTERSECT_IMPL")}
    try:
        return _run_fused(args)
    finally:
        for var, val in saved.items():
            if val is not None:
                os.environ[var] = val


def _run_fused(args) -> Table:
    from repro.core.engine_jax import classify_fusable_dbqs
    from repro.core.executor import ExecutorConfig, make_executor
    from repro.core.instructions import var_name
    from repro.core.pattern import get_pattern
    from repro.core.plangen import generate_best_plan
    from repro.graph.generate import powerlaw

    g = powerlaw(args.n, args.deg, seed=args.seed)
    # small conformance-gate config: Pallas interpret mode traces the grid
    # step by step on CPU, so the fused gate runs on a clipped-caps shape
    # (the bytes table below prices the full run from the unfused engine's
    # measured occupancy — the fused path's bytes follow from the plan's
    # fusability classification, not from re-running it at scale)
    g_gate = powerlaw(args.gate_n, args.deg, seed=args.seed)
    t = Table("GPU fetch path: achieved vs lane-math bytes per DBQ level "
              f"(n={args.n} m={g.m} batch={args.batch}; fused drops the "
              "materialize+re-read round trip)",
              ["pattern", "dbq", "lvl", "fused", "rows ach", "rows lane",
               "D", "MB unfused", "MB fused", "saving"])
    payload_rows = []
    totals = {"unfused_bytes": 0, "fused_bytes": 0,
              "unfused_bytes_lane": 0, "fused_bytes_lane": 0}
    times = {}
    for pname in args.patterns:
        plan = generate_best_plan(get_pattern(pname), g.stats())
        t0 = time.perf_counter()
        # fused=False pins the unfused baseline even when the CI cell's
        # REPRO_FUSED_FETCH toggle is exported
        ex_un = make_executor("jax", fused=False)
        st_un = ex_un.run(plan, g, batch=args.batch)
        t_un = time.perf_counter() - t0
        # exactness gate: the fused interpret path must agree bit for bit
        plan_gate = generate_best_plan(get_pattern(pname), g_gate.stats())
        from repro.core.executor import plan_enu_count
        gate_caps = [args.gate_cap] * plan_enu_count(plan_gate)
        gate_cfg = dict(batch=args.gate_batch, caps=gate_caps,
                        max_retries=12)
        un_gate = make_executor("jax", fused=False).run(plan_gate, g_gate,
                                                        **gate_cfg)
        t0 = time.perf_counter()
        st_fu = make_executor(
            "jax-gpu", gather_intersect_impl="interpret").run(
                plan_gate, g_gate, **gate_cfg)
        t_fu = time.perf_counter() - t0
        assert un_gate.count == st_fu.count, (pname, un_gate.count,
                                              st_fu.count)
        assert st_fu.extras["fused_fetch"]
        times[pname] = {"unfused_s": t_un,
                        "fused_gate_interpret_s": t_fu,
                        "count": st_un.count,
                        "gate_count": st_fu.count}
        levels = st_un.extras["level_sizes"]
        be = ex_un.backend            # already prepared by the run above
        caps = be.initial_caps(ExecutorConfig(batch=args.batch))
        D = be.dg.d
        n_chunks = -(-g.n // args.batch)
        fusable = classify_fusable_dbqs(plan)
        row_bytes = D * 4
        for target, lvl in _dbq_levels(plan):
            ach = int(g.n if lvl < 0 else levels[lvl])
            lane = int(n_chunks * (args.batch if lvl < 0 else caps[lvl]))
            fused = target in fusable
            # unfused: read the adjacency rows, write the gathered block,
            # re-read it at the consuming INT; fused: one streamed read
            un_b = 3 * ach * row_bytes
            fu_b = (1 if fused else 3) * ach * row_bytes
            un_l = 3 * lane * row_bytes
            fu_l = (1 if fused else 3) * lane * row_bytes
            totals["unfused_bytes"] += un_b
            totals["fused_bytes"] += fu_b
            totals["unfused_bytes_lane"] += un_l
            totals["fused_bytes_lane"] += fu_l
            t.add(pname, var_name(target), lvl + 1,
                  "yes" if fused else "-", ach, lane, D,
                  f"{un_b / 1e6:.2f}", f"{fu_b / 1e6:.2f}",
                  f"{un_b / max(fu_b, 1):.1f}x")
            payload_rows.append(dict(
                pattern=pname, dbq=var_name(target), level=lvl + 1,
                fused=fused, rows_achieved=ach, rows_lane_math=lane,
                row_width=D, unfused_bytes=un_b, fused_bytes=fu_b,
                unfused_bytes_lane=un_l, fused_bytes_lane=fu_l))
    per_edge = {k: v / max(g.m, 1) for k, v in totals.items()}
    t.add("TOTAL", "-", "-", "-", "-", "-", "-",
          f"{totals['unfused_bytes'] / 1e6:.2f}",
          f"{totals['fused_bytes'] / 1e6:.2f}",
          f"{totals['unfused_bytes'] / max(totals['fused_bytes'], 1):.1f}x")
    t.show()
    print(f"\nbytes/edge (achieved): unfused "
          f"{per_edge['unfused_bytes']:,.0f}  fused "
          f"{per_edge['fused_bytes']:,.0f}")
    print(f"bytes/edge (lane math): unfused "
          f"{per_edge['unfused_bytes_lane']:,.0f}  fused "
          f"{per_edge['fused_bytes_lane']:,.0f}")
    print("(the fused column is gated for exactness on a small "
          f"interpret-mode run, n={args.gate_n} caps={args.gate_cap}; "
          "the bytes columns, not the CPU seconds, are the accelerator "
          "claim)")
    for pname, tm in times.items():
        print(f"  {pname:10s} count {tm['count']:>8}  unfused "
              f"{tm['unfused_s']:.2f}s  fused gate(interpret) "
              f"{tm['fused_gate_interpret_s']:.2f}s")
    path = args.json or os.path.join(ROOT, "BENCH_gpu_fetch.json")
    payload = dict(benchmark="gpu_fetch", title=t.title,
                   graph=dict(n=g.n, m=g.m, batch=args.batch,
                              seed=args.seed),
                   columns=t.columns,
                   rows=[[str(x) for x in r] for r in t.rows],
                   levels=payload_rows, totals=totals,
                   bytes_per_edge=per_edge, times=times)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(payload_rows)} DBQ levels)")
    return t


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fused", action="store_true",
                    help="fused vs unfused fetch-path bytes model "
                         "(writes BENCH_gpu_fetch.json)")
    ap.add_argument("--result-dir", default=None)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--deg", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--gate-n", type=int, default=96,
                    help="--fused: graph size of the interpret-mode "
                         "exactness gate (kept small: the Pallas "
                         "interpreter traces the grid step by step)")
    ap.add_argument("--gate-batch", type=int, default=16)
    ap.add_argument("--gate-cap", type=int, default=256,
                    help="--fused: per-level cap of the gate run (the "
                         "driver re-splits on overflow, so small caps "
                         "stay exact)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--patterns", nargs="*", default=list(FUSED_PATTERNS))
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    if args.fused:
        run_fused(args)
    else:
        run(args.result_dir).show()


if __name__ == "__main__":
    main()
