"""§Roofline table: reads the dry-run JSONs and prints the three terms per
(arch x shape x mesh), the dominant bottleneck, and useful-FLOP ratios."""

from __future__ import annotations

import glob
import json
import os

from .common import Table

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(result_dir: str = None) -> Table:
    dirs = ([result_dir] if result_dir else
            [os.path.join(ROOT, "results", d)
             for d in ("dryrun", "dryrun_final_multipod", "dryrun_opt",
                       "dryrun_opt2")])
    t = Table("Roofline terms per cell (per-chip seconds; v5e constants)",
              ["cell", "mesh", "variant", "mem GiB/dev", "compute ms",
               "memory ms", "collective ms", "dominant", "useful-FLOP %"])
    any_files = False
    for d in dirs:
        variant = ("optimized" if "opt" in os.path.basename(d)
                   else "baseline")
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            any_files = True
            with open(f) as fh:
                r = json.load(fh)
            ro = r["roofline"]
            t.add(f"{r['arch']}:{r['shape']}",
                  "2pod" if "pod,data" in r["mesh"] else "1pod",
                  variant if variant == "baseline"
                  else f"opt:{r.get('sharding_mode', '-')}",
                  f"{r['memory_analysis']['peak_bytes_per_device'] / 2**30:.2f}",
                  f"{ro['compute_s'] * 1e3:.2f}",
                  f"{ro['memory_s'] * 1e3:.1f}",
                  f"{ro['collective_s'] * 1e3:.2f}",
                  ro["dominant"],
                  f"{ro['useful_flops_ratio'] * 100:.0f}"
                  if ro["useful_flops_ratio"] else "-")
    if not any_files:
        print(f"(no dry-run results under {dirs}; run "
              "`python -m repro.launch.dryrun --all` first)")
    return t


if __name__ == "__main__":
    run().show()
