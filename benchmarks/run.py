"""Benchmark driver: one suite per paper table/figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [suite ...]
"""

from __future__ import annotations

import sys
import time


# every enumeration suite routes through the unified Executor API
# (repro/core/executor.py) — one chunking/overflow policy across engines
SUITES = ["plan_search", "plan_opts", "cache", "conformance", "task_split",
          "vs_join", "sbenu_bench", "scaling", "roofline"]


def main() -> None:
    want = sys.argv[1:] or SUITES
    failures = []
    for name in want:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run().show()
            print(f"[{name}: {time.time() - t0:.1f}s]")
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"[{name} FAILED: {e}]")
    if failures:
        raise SystemExit(f"failed suites: {[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
