"""Fig. 12 reproduction + streaming-engine throughput: S-BENU per time step.

Three comparisons, all per time step of a random update stream:

* interpreter (``SBenuRefEngine`` behind the unified Executor) vs the
  vectorized JIT delta-frontier engine (``sbenu-jax``) — the headline of
  the vectorization work: >= 10x on a >= 10k-vertex dynamic graph;
* interpreter vs ``sbenu-jax`` vs ``sbenu-dist`` (the shard_map SPMD
  engine over the mesh-sharded six-block snapshot) — the scaling table
  for the distributed streaming path (``--dist``; run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` or on a real
  mesh for multi-shard numbers);
* incremental enumeration vs recompute-from-scratch (the Delta-BiGJoin
  comparison class) — kept from the original Fig. 12 table.

CLI::

    PYTHONPATH=src python benchmarks/sbenu_bench.py \
        [--n 10000 --edges 50000 --steps 3 --update-batch 2000] [--dist]
    PYTHONPATH=src python benchmarks/sbenu_bench.py --smoke   # CI gate

``--smoke`` runs a small stream and *asserts* count conformance between
the interpreter, the JIT engine, and the mesh engine, so every push
exercises the streaming paths; it writes ``BENCH_sbenu.json`` and
``BENCH_sbenu_dist.json`` into the repo root (committed with the PR, so
the perf trajectory is tracked in-repo) unless ``--json`` points
elsewhere.
"""

from __future__ import annotations

import argparse
import os
import time

from repro.core.estimate import GraphStats
from repro.core.executor import SBenuDistBackend, SBenuJaxBackend
from repro.core.pattern import get_pattern
from repro.core.sbenu import (enumerate_matches_digraph,
                              generate_best_sbenu_plans, run_timestep)
from repro.core.symmetry import symmetry_breaking_constraints
from repro.graph.dynamic import SnapshotStore, stream_width_floors
from repro.graph.generate import edge_stream

try:
    from .common import Table
except ImportError:                      # run as a script: python benchmarks/…
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import Table

#: default landing spot for BENCH_*.json artifacts: the repo root, so the
#: smoke numbers are committed alongside the code they measure
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_stream(pname: str, n: int, m_init: int, steps: int,
                 update_batch: int, seed: int = 5, chunk: int = 1024,
                 run_ref: bool = True, table: Table = None) -> float:
    """Run one stream on both engines; returns the steady-state speedup
    (interpreter time / JIT time, excluding the compile step)."""
    p = get_pattern(pname)
    g0, batches = edge_stream(n=n, m_init=m_init, steps=steps,
                              batch=update_batch, seed=seed)
    stats = GraphStats(n, m_init, delta_edges=update_batch)
    plans = generate_best_sbenu_plans(p, stats)
    d, dd = stream_width_floors(g0, batches)
    store_ref = SnapshotStore(g0)
    store_jax = SnapshotStore(g0)
    backend = SBenuJaxBackend(collect="counts", d_min=d, delta_d_min=dd)
    speedups = []
    for step, batch in enumerate(batches, 1):
        if run_ref:
            t0 = time.perf_counter()
            _, _, ctr_r = run_timestep(p, plans, store_ref, batch,
                                       engine="ref", collect="counts",
                                       chunk=chunk)
            t_ref = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, _, ctr_j = run_timestep(p, plans, store_jax, batch,
                                   collect="counts", chunk=chunk,
                                   backend=backend)
        t_jit = time.perf_counter() - t0
        if run_ref:
            assert (ctr_r.matches_plus, ctr_r.matches_minus) == \
                (ctr_j.matches_plus, ctr_j.matches_minus), \
                f"engine mismatch at step {step}"
            sp = t_ref / max(t_jit, 1e-9)
            if step > 1:                  # step 1 pays JIT compilation
                speedups.append(sp)
            if table is not None:
                table.add(pname, step, ctr_j.matches_plus,
                          ctr_j.matches_minus, f"{t_ref:.3f}",
                          f"{t_jit:.3f}", f"{sp:.1f}x")
        elif table is not None:
            table.add(pname, step, ctr_j.matches_plus, ctr_j.matches_minus,
                      "-", f"{t_jit:.3f}", "-")
    return (sum(speedups) / len(speedups)) if speedups else 0.0


def bench_stream3(pname: str, n: int, m_init: int, steps: int,
                  update_batch: int, seed: int = 5, chunk: int = 1024,
                  run_ref: bool = True, hot: int = 0,
                  rebalance: bool = False, table: Table = None) -> None:
    """One stream mirrored into three stores: interpreter vs the JIT
    engine vs the shard_map mesh engine, per time step. Counts are
    asserted equal across all engines on every step."""
    p = get_pattern(pname)
    g0, batches = edge_stream(n=n, m_init=m_init, steps=steps,
                              batch=update_batch, seed=seed)
    stats = GraphStats(n, m_init, delta_edges=update_batch)
    plans = generate_best_sbenu_plans(p, stats)
    d, dd = stream_width_floors(g0, batches)
    stores = {e: SnapshotStore(g0) for e in ("ref", "jax", "dist")}
    backends = {
        "jax": SBenuJaxBackend(collect="counts", d_min=d, delta_d_min=dd),
        "dist": SBenuDistBackend(collect="counts", d_min=d, delta_d_min=dd,
                                 hot=hot, rebalance=rebalance),
    }
    for step, batch in enumerate(batches, 1):
        times, counts = {}, {}
        if run_ref:
            t0 = time.perf_counter()
            _, _, ctr = run_timestep(p, plans, stores["ref"], batch,
                                     engine="ref", collect="counts",
                                     chunk=chunk)
            times["ref"] = time.perf_counter() - t0
            counts["ref"] = (ctr.matches_plus, ctr.matches_minus)
        for e in ("jax", "dist"):
            t0 = time.perf_counter()
            _, _, ctr = run_timestep(p, plans, stores[e], batch,
                                     collect="counts", chunk=chunk,
                                     backend=backends[e])
            times[e] = time.perf_counter() - t0
            counts[e] = (ctr.matches_plus, ctr.matches_minus)
        assert len(set(counts.values())) == 1, \
            f"engine mismatch at step {step}: {counts}"
        dp, dm = counts["jax"]
        if table is not None:
            table.add(pname, step, dp, dm,
                      f"{times['ref']:.3f}" if run_ref else "-",
                      f"{times['jax']:.3f}", f"{times['dist']:.3f}",
                      f"{times['jax'] / max(times['dist'], 1e-9):.2f}x")


def run() -> Table:
    t = Table("Fig. 12 + streaming engines: interpreter vs sbenu-jax "
              "(per step)",
              ["pattern", "step", "dR+", "dR-", "interp s", "jit s",
               "speedup"])
    for pname in ("q1'", "q3'"):
        bench_stream(pname, n=2000, m_init=10000, steps=3,
                     update_batch=400, table=t)
    return t


def run_scratch() -> Table:
    """The original Fig. 12 competitor: recompute-from-scratch."""
    t = Table("Fig. 12: S-BENU vs recompute-from-scratch (per step)",
              ["pattern", "step", "dR+", "dR-", "sbenu s", "scratch s",
               "speedup"])
    for pname in ("q1'", "q3'"):
        p = get_pattern(pname)
        g0, batches = edge_stream(n=120, m_init=600, steps=3, batch=40,
                                  seed=5)
        store = SnapshotStore(g0)
        stats = GraphStats(120, 600, delta_edges=40)
        plans = generate_best_sbenu_plans(p, stats)
        cons = symmetry_breaking_constraints(p)
        for step, batch in enumerate(batches, 1):
            prev = store.snapshot("prev")
            t0 = time.perf_counter()
            dp, dm, _ = run_timestep(p, plans, store, batch)
            t_inc = time.perf_counter() - t0
            cur = store.snapshot("prev")
            t0 = time.perf_counter()
            r_prev = enumerate_matches_digraph(p, prev, cons)
            r_cur = enumerate_matches_digraph(p, cur, cons)
            want_p, want_m = r_cur - r_prev, r_prev - r_cur
            t_scr = time.perf_counter() - t0
            assert dp == want_p and dm == want_m
            t.add(pname, step, len(dp), len(dm), f"{t_inc:.3f}",
                  f"{t_scr:.3f}", f"{t_scr / max(t_inc, 1e-9):.1f}x")
    return t


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="q1'")
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--edges", type=int, default=50000)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--update-batch", type=int, default=2000)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--no-ref", action="store_true",
                    help="skip the interpreter (large streams)")
    ap.add_argument("--scratch", action="store_true",
                    help="also run the Fig. 12 recompute-from-scratch "
                         "comparison")
    ap.add_argument("--dist", action="store_true",
                    help="run the interpreter-vs-jit-vs-dist table "
                         "instead of the two-engine one")
    ap.add_argument("--smoke", action="store_true",
                    help="small stream + conformance assert (CI gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the result table as a JSON artifact "
                         "(default: BENCH_sbenu.json in the repo root "
                         "when --smoke)")
    args = ap.parse_args()

    def emit(table, path, name="sbenu"):
        if path:
            import json
            payload = dict(benchmark=name, title=table.title,
                           columns=table.columns,
                           rows=[[str(x) for x in r] for r in table.rows])
            with open(path, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"wrote {path} ({len(table.rows)} rows)")

    dist_cols = ["pattern", "step", "dR+", "dR-", "interp s", "jit s",
                 "dist s", "jit/dist"]
    if args.smoke:
        t = Table("sbenu_bench --smoke: interpreter vs sbenu-jax",
                  ["pattern", "step", "dR+", "dR-", "interp s", "jit s",
                   "speedup"])
        for pname in ("q1'", "q3'"):
            bench_stream(pname, n=300, m_init=1500, steps=2,
                         update_batch=100, seed=args.seed, chunk=64,
                         table=t)
        t.show()
        emit(t, args.json or os.path.join(ROOT, "BENCH_sbenu.json"))
        td = Table("sbenu_bench --smoke: interpreter vs sbenu-jax vs "
                   "sbenu-dist", dist_cols)
        bench_stream3("q1'", n=300, m_init=1500, steps=2,
                      update_batch=100, seed=args.seed, chunk=64, table=td)
        td.show()
        # the dist artifact follows --json: <base>_dist.json next to it
        dist_path = (os.path.splitext(args.json)[0] + "_dist.json"
                     if args.json
                     else os.path.join(ROOT, "BENCH_sbenu_dist.json"))
        emit(td, dist_path, name="sbenu_dist")
        run_scratch().show()             # asserts vs the snapshot diff
        print("smoke OK: interpreter == sbenu-jax == sbenu-dist on every "
              "step, incremental == recompute-from-scratch diff")
        return
    if args.scratch:
        run_scratch().show()
    if args.dist:
        td = Table(f"S-BENU streaming engines (3-way) on n={args.n} "
                   f"m={args.edges} ({args.update_batch} updates/step)",
                   dist_cols)
        bench_stream3(args.pattern, n=args.n, m_init=args.edges,
                      steps=args.steps, update_batch=args.update_batch,
                      seed=args.seed, chunk=args.chunk,
                      run_ref=not args.no_ref, table=td)
        td.show()
        emit(td, args.json, name="sbenu_dist")
        return
    t = Table(f"S-BENU streaming engines on n={args.n} m={args.edges} "
              f"({args.update_batch} updates/step)",
              ["pattern", "step", "dR+", "dR-", "interp s", "jit s",
               "speedup"])
    sp = bench_stream(args.pattern, n=args.n, m_init=args.edges,
                      steps=args.steps, update_batch=args.update_batch,
                      seed=args.seed, chunk=args.chunk,
                      run_ref=not args.no_ref, table=t)
    t.show()
    emit(t, args.json)
    if not args.no_ref:
        print(f"\nsteady-state speedup (steps >= 2): {sp:.1f}x")


if __name__ == "__main__":
    main()
