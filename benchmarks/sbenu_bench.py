"""Fig. 12 reproduction: S-BENU incremental enumeration vs recompute-from-
scratch, per time step (the Delta-BiGJoin comparison class)."""

from __future__ import annotations

import time

from repro.core.estimate import GraphStats
from repro.core.pattern import get_pattern
from repro.core.sbenu import (enumerate_matches_digraph,
                              generate_best_sbenu_plans, run_timestep)
from repro.core.symmetry import symmetry_breaking_constraints
from repro.graph.dynamic import SnapshotStore
from repro.graph.generate import edge_stream

from .common import Table


def run() -> Table:
    t = Table("Fig. 12: S-BENU vs recompute-from-scratch (per step)",
              ["pattern", "step", "dR+", "dR-", "sbenu s", "scratch s",
               "speedup"])
    for pname in ("q1'", "q3'"):
        p = get_pattern(pname)
        g0, batches = edge_stream(n=120, m_init=600, steps=3, batch=40,
                                  seed=5)
        store = SnapshotStore(g0)
        stats = GraphStats(120, 600, delta_edges=40)
        plans = generate_best_sbenu_plans(p, stats)
        cons = symmetry_breaking_constraints(p)
        for step, batch in enumerate(batches, 1):
            prev = store.snapshot("prev")
            t0 = time.perf_counter()
            dp, dm, _ = run_timestep(p, plans, store, batch)
            t_inc = time.perf_counter() - t0
            # recompute-from-scratch competitor
            cur = store.snapshot("prev")
            t0 = time.perf_counter()
            r_prev = enumerate_matches_digraph(p, prev, cons)
            r_cur = enumerate_matches_digraph(p, cur, cons)
            want_p, want_m = r_cur - r_prev, r_prev - r_cur
            t_scr = time.perf_counter() - t0
            assert dp == want_p and dm == want_m
            t.add(pname, step, len(dp), len(dm), f"{t_inc:.3f}",
                  f"{t_scr:.3f}", f"{t_scr / max(t_inc, 1e-9):.1f}x")
    return t


if __name__ == "__main__":
    run().show()
