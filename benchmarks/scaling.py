"""Figs. 13-14 reproduction: machine scalability.

Per-shard work / communication as the shard count grows (the structural
analogue of the paper's wall-clock speedup curves — on one CPU we report
the quantities that determine speedup: max per-shard work, total remote
rows, skew with/without rebalancing, and hot-row cache effect)."""

from __future__ import annotations

import os
import subprocess
import sys

from .common import Table

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import json, numpy as np
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.engine_dist import enumerate_distributed
from repro.graph.generate import powerlaw
g = powerlaw(300, 4, seed=6)
P = get_pattern("chordal-square")
plan = generate_best_plan(P, g.stats())
out = []
for hot, reb in ((0, False), (32, False), (32, True)):
    st = enumerate_distributed(plan, g, batch_per_shard=32, hot=hot,
                               rebalance=reb)
    lv = st.per_shard_level_sizes
    out.append(dict(hot=hot, reb=reb, count=st.count,
                    cold=st.cold_rows_fetched,
                    max_work=int(lv[-1].max()) if len(lv) else 0,
                    min_work=int(lv[-1].min()) if len(lv) else 0))
print(json.dumps(out))
"""


def run() -> Table:
    t = Table("Figs. 13-14: scalability drivers vs shard count",
              ["shards", "hot", "rebalance", "matches", "remote rows",
               "final-level max/min work"])
    for shards in (2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={shards}"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        res = subprocess.run([sys.executable, "-c", _CODE],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        if res.returncode != 0:
            raise RuntimeError(res.stderr[-2000:])
        import json
        for r in json.loads(res.stdout.strip().splitlines()[-1]):
            t.add(shards, r["hot"], r["reb"], r["count"], r["cold"],
                  f"{r['max_work']}/{r['min_work']}")
    return t


if __name__ == "__main__":
    run().show()
