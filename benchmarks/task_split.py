"""Fig. 11 reproduction: task splitting evens the per-task work
distribution (power-law graphs make unsplit tasks heavily skewed).

Routed through the unified Executor API: the ref backend θ-splits heavy
start vertices into C2 slices, and the driver surfaces per-task work via
``ExecStats.extras`` — the same accounting every engine shares."""

from __future__ import annotations

import numpy as np

from repro.core.executor import make_executor
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.graph.generate import powerlaw

from .common import Table


def run() -> Table:
    g = powerlaw(400, 5, seed=3)
    p = get_pattern("triangle")
    plan = generate_best_plan(p, g.stats())
    t = Table("Fig. 11: task splitting (per-task work distribution)",
              ["theta", "tasks", "max", "p99", "mean", "matches"])
    for theta in (None, 64, 16, 4):
        st = make_executor("ref").run(plan, g, theta=theta, batch=64)
        w = np.array(st.extras["per_task_work"])
        t.add("inf" if theta is None else theta, len(w), int(w.max()),
              int(np.percentile(w, 99)), f"{w.mean():.1f}", st.count)
    return t


if __name__ == "__main__":
    run().show()
