"""Fig. 11 reproduction: task splitting evens the per-task work
distribution (power-law graphs make unsplit tasks heavily skewed)."""

from __future__ import annotations

import numpy as np

from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.ref_engine import RefEngine
from repro.graph.generate import powerlaw

from .common import Table


def run() -> Table:
    g = powerlaw(400, 5, seed=3)
    p = get_pattern("triangle")
    plan = generate_best_plan(p, g.stats())
    t = Table("Fig. 11: task splitting (per-task work distribution)",
              ["theta", "tasks", "max", "p99", "mean", "matches"])
    for theta in (None, 64, 16, 4):
        eng = RefEngine(plan, p, g)
        eng.run(theta=theta)
        w = np.array(eng.counters.per_task_work)
        t.add("inf" if theta is None else theta, len(w), int(w.max()),
              int(np.percentile(w, 99)), f"{w.mean():.1f}",
              eng.counters.matches)
    return t


if __name__ == "__main__":
    run().show()
