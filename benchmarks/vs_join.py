"""Tables 5-6 reproduction: BENU vs the BFS-style join baseline.

The paper's headline: join frameworks shuffle partial-match tables (bytes
~ intermediate result size); BENU moves only on-demand adjacency rows. We
run both on the same graphs and report wall time + bytes moved:
    join: sum of intermediate table bytes (hash repartition per join)
    BENU: distinct adjacency rows fetched x padded row bytes

The BENU side runs through the unified Executor API (ref backend with a
capacity-bounded DB cache); the remote-row count comes straight from the
driver's ``ExecStats.extras``.
"""

from __future__ import annotations

import time

from repro.core.baseline_join import enumerate_join
from repro.core.executor import make_executor
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.ref_engine import GraphDB
from repro.graph.generate import powerlaw

from .common import Table


def run() -> Table:
    g = powerlaw(500, 5, seed=4)
    t = Table("Tables 5-6: BENU vs BFS-style edge join",
              ["pattern", "matches", "join s", "join MB moved",
               "benu s", "benu MB moved", "comm ratio"])
    row_bytes = 4 * (int(g.deg.max()) + 127) // 128 * 128
    for pname in ("q1", "q2", "q3", "q4", "q6"):
        p = get_pattern(pname)
        t0 = time.perf_counter()
        js = enumerate_join(p, g)
        t_join = time.perf_counter() - t0
        plan = generate_best_plan(p, g.stats())
        db = GraphDB(g, cache_capacity=g.n // 10)
        t0 = time.perf_counter()
        st = make_executor("ref", db=db).run(plan, g, batch=64)
        t_benu = time.perf_counter() - t0
        assert st.count == js.matches, (pname, js.matches, st.count)
        benu_bytes = st.extras["remote_queries"] * row_bytes
        ratio = js.bytes_shuffled / max(benu_bytes, 1)
        t.add(pname, js.matches, f"{t_join:.2f}",
              f"{js.bytes_shuffled / 1e6:.1f}", f"{t_benu:.2f}",
              f"{benu_bytes / 1e6:.1f}", f"{ratio:.1f}x")
    return t


if __name__ == "__main__":
    run().show()
