"""Continuous subgraph enumeration with S-BENU (paper §5).

Streams batch updates over a dynamic directed graph and reports the
appearing/disappearing matches of a directed pattern at each time step,
validating each step against the brute-force snapshot diff.

    PYTHONPATH=src python examples/continuous_enum.py
"""

from repro.core.estimate import GraphStats
from repro.core.pattern import get_pattern
from repro.core.sbenu import (generate_best_sbenu_plans, run_timestep,
                              snapshot_diff_oracle)
from repro.graph.dynamic import SnapshotStore
from repro.graph.generate import edge_stream

p = get_pattern("q3'")        # directed triangle + 2-path chord
g0, batches = edge_stream(n=150, m_init=900, steps=5, batch=60, seed=1)
store = SnapshotStore(g0)

plans = generate_best_sbenu_plans(
    p, GraphStats(150, 900, delta_edges=60))
print(f"{p.name}: {len(plans)} incremental execution plans "
      f"(one per pattern edge)\n")
print("plan for the first incremental pattern graph dP_1:")
print(plans[0].pretty())

print("\nstep |  dR+  |  dR-  | DBQ queries")
for t, batch in enumerate(batches, 1):
    want = snapshot_diff_oracle(p, store, batch)
    dp, dm, ctr = run_timestep(p, plans, store, batch)
    assert (dp, dm) == want
    print(f"{t:4d} | {len(dp):5d} | {len(dm):5d} | {ctr.dbq}")
print("\nall steps validated against the snapshot-diff oracle")
