"""BENU as a motif-count feature extractor for a GNN (substrate crossover).

Counts per-vertex triangle/square participation with BENU (collecting
matches, not just counts), attaches them as node features, and trains the
assigned GIN architecture on a synthetic task where motif counts carry the
label signal — the point where the paper's technique feeds the GNN stack.

    PYTHONPATH=src python examples/motif_features.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine_jax import enumerate_graph
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.graph.batch import GraphBatch
from repro.graph.generate import powerlaw
from repro.graph.storage import edge_index_from_graph
from repro.models.gnn import GNNConfig, gnn_loss, init_gnn_params
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.optimizer import AdamWConfig

g = powerlaw(300, 4, seed=7)

# --- per-vertex motif counts via BENU (matches collected) ---------------
feats = np.zeros((g.n, 2), np.float32)
for j, pname in enumerate(("triangle", "square")):
    p = get_pattern(pname)
    plan = generate_best_plan(p, g.stats())
    res = enumerate_graph(plan, g, batch=64, collect_matches=True)
    for match in res["matches"]:
        for v in match:
            feats[v, j] += 1.0
print(f"motif features: triangles total={int(feats[:, 0].sum())}, "
      f"squares total={int(feats[:, 1].sum())}")
feats = np.log1p(feats)

# --- labels derived from motif participation (learnable signal) ---------
labels = (feats[:, 0] > np.median(feats[:, 0])).astype(np.int32)

ei = edge_index_from_graph(g)
batch = GraphBatch(
    x=feats, edge_src=ei[0], edge_dst=ei[1], labels=labels, n_nodes=g.n,
    node_mask=np.ones(g.n, bool), loss_mask=np.ones(g.n, bool)).as_arrays()

cfg = GNNConfig("gin-motif", "gin", n_layers=3, d_hidden=32, d_feat=2,
                n_out=2)
hist = run_training(
    lambda p_, b: gnn_loss(p_, b, cfg),
    lambda: init_gnn_params(jax.random.PRNGKey(0), cfg),
    lambda step: batch,
    AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100),
    TrainLoopConfig(steps=100, ckpt_every=1000, log_every=25))
print(f"GIN on BENU motif features: loss {hist['loss'][0]:.3f} -> "
      f"{hist['loss'][-1]:.3f}")
assert hist["loss"][-1] < hist["loss"][0]
