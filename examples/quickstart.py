"""Quickstart: compile a best execution plan and enumerate a pattern.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.engine_jax import enumerate_graph
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.ref_engine import count_isomorphic_subgraphs
from repro.graph.generate import powerlaw

# 1. a data graph (power-law, like the paper's social networks)
g = powerlaw(n=500, m_per_node=4, seed=0)
print(f"data graph: {g.n} vertices, {g.m} edges")

# 2. the pattern: the chordal square (core of the paper's hard patterns)
p = get_pattern("chordal-square")

# 3. Alg. 3: search matching orders, apply CSE/reordering/triangle-cache
plan = generate_best_plan(p, g.stats())
print("\nbest execution plan (paper §4):")
print(plan.pretty())

# 4. run the vectorized frontier engine (the TPU-native executor)
result = enumerate_graph(plan, g, batch=128)
print(f"\nmatches found: {result['count']}")

# 5. cross-check against brute force
expected = count_isomorphic_subgraphs(p, g)
assert result["count"] == expected, (result["count"], expected)
print(f"brute-force check: {expected} — OK")
