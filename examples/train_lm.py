"""End-to-end LM training driver with checkpoint/restart.

Trains a reduced qwen2-family config (the full 0.5B at seq 4k needs the
TPU pod; the same code path scales — launch/train.py) for a few hundred
steps on the synthetic compressible token stream, checkpointing every 50
steps. Re-running the script resumes from the last checkpoint.

    PYTHONPATH=src python examples/train_lm.py [steps]
"""

import sys

import jax
import jax.numpy as jnp

from repro.data.pipelines import LMStream
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.optimizer import AdamWConfig

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200

cfg = LMConfig(name="qwen2-micro", n_layers=4, d_model=256, n_heads=8,
               n_kv_heads=2, d_head=32, d_ff=1024, vocab=4096,
               qkv_bias=True, tie_embeddings=True, dtype=jnp.float32,
               remat=False)
print(f"model: {cfg.n_params / 1e6:.1f}M params")

stream = LMStream(vocab=cfg.vocab, seq_len=256, global_batch=8)
ckpt = CheckpointManager("/tmp/repro_lm_ckpt", keep=2)

hist = run_training(
    lambda p, b: loss_fn(p, b, cfg),
    lambda: init_params(jax.random.PRNGKey(0), cfg),
    stream.batch,
    AdamWConfig(lr=6e-4, warmup_steps=20, decay_steps=steps),
    TrainLoopConfig(steps=steps, ckpt_every=50, log_every=20),
    ckpt=ckpt)
print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
      f"(checkpoints in /tmp/repro_lm_ckpt)")
