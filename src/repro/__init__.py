"""repro package."""
