"""Version compatibility shims for jax.

The repo targets the modern ``jax.shard_map`` API (with its ``check_vma``
argument). Older jax releases only ship
``jax.experimental.shard_map.shard_map`` whose equivalent knob is named
``check_rep``. Every call site goes through :func:`shard_map` below so the
rest of the codebase is written once against the new API.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Dict, Optional

import jax

__all__ = ["shard_map", "cost_analysis_dict"]


def _replication_kwarg(fn: Callable) -> Optional[str]:
    """The replication-check kwarg this shard_map takes: jax renamed
    ``check_rep`` to ``check_vma`` after promoting shard_map out of
    experimental, so dispatch on the signature, not the module."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - C callables
        return "check_vma"
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


def shard_map(f: Callable, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None, **kwargs):
    """``jax.shard_map`` with fallback to the experimental module.

    ``check_vma`` (new-style name) maps to whatever replication-check
    kwarg the installed jax accepts; other keyword arguments pass
    through.
    """
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    if check_vma is not None:
        kw = _replication_kwarg(sm)
        if kw is not None:
            kwargs[kw] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` as a flat dict across jax versions.

    Newer jax returns one dict; older versions return a list with one dict
    per SPMD partition (all partitions identical for our single-module
    programs). Missing/empty analyses normalize to ``{}``.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)
