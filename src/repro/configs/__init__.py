"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

The ten assigned architectures (exact published configs) plus ``benu`` —
the paper's own technique as a dry-runnable architecture.
"""

from __future__ import annotations

from typing import Dict, List

from .base import ArchSpec, ShapeSpec  # noqa: F401 (re-export)

_MODULES = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen2.5-3b": "qwen2_5_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "meshgraphnet": "meshgraphnet",
    "pna": "pna",
    "egnn": "egnn",
    "gin-tu": "gin_tu",
    "bst": "bst",
    "benu": "benu",
}

ASSIGNED = [a for a in _MODULES if a != "benu"]


def get_config(name: str) -> ArchSpec:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    import importlib
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SPEC


def list_archs(include_benu: bool = True) -> List[str]:
    return list(_MODULES) if include_benu else list(ASSIGNED)


def all_cells(include_benu: bool = False) -> List[tuple]:
    """Every (arch, shape) pair of the dry-run matrix (40 assigned cells)."""
    cells = []
    for a in list_archs(include_benu):
        spec = get_config(a)
        for s in spec.shapes:
            cells.append((a, s))
    return cells
