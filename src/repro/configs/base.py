"""Architecture/shape registry plumbing.

Every assigned architecture ships as an :class:`ArchSpec`:
    * the exact published model config,
    * its assigned shape set (each cell of the dry-run matrix),
    * ``input_specs(shape)`` — ShapeDtypeStruct stand-ins for every input
      (weak-type-correct, shardable, never allocated),
    * ``smoke()`` — a reduced same-family config for CPU smoke tests.

Shape-kind vocabulary (drives which step function the launcher lowers):
    lm_train | lm_prefill | lm_decode | lm_long_decode
    gnn_full | gnn_minibatch | gnn_molecule
    rec_train | rec_serve | rec_retrieval
    benu_enum
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def pad512(n: int) -> int:
    """Edge/candidate arrays are padded to a multiple of 512 (the largest
    mesh) so they shard evenly; sentinel-padded entries are no-ops in the
    segment-sum / scoring paths."""
    return -(-n // 512) * 512


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str
    dims: Dict[str, int]          # e.g. {"seq": 4096, "batch": 256}
    note: str = ""


@dataclass
class ArchSpec:
    name: str
    family: str                   # lm | gnn | recsys | benu
    model_cfg: Any
    shapes: Dict[str, ShapeSpec]
    source: str = ""              # citation tag from the assignment
    applicability: str = ""       # §Arch-applicability note
    smoke_builder: Optional[Callable[[], "ArchSpec"]] = None

    # ------------------------------------------------------------- input specs
    def input_specs(self, shape_name: str) -> Dict[str, Any]:
        sp = self.shapes[shape_name]
        fam, cfg = self.family, self.model_cfg
        d = sp.dims
        if fam == "lm":
            if sp.kind == "lm_train":
                return {"tokens": sds((d["batch"], d["seq"]), i32),
                        "labels": sds((d["batch"], d["seq"]), i32)}
            if sp.kind == "lm_prefill":
                return {"tokens": sds((d["batch"], d["seq"]), i32)}
            if sp.kind in ("lm_decode", "lm_long_decode"):
                return {"tokens": sds((d["batch"], 1), i32)}
            raise KeyError(sp.kind)
        if fam == "gnn":
            n, e = d["n_nodes"], pad512(d["n_edges"])
            specs = {"x": sds((n, d["d_feat"]), f32),
                     "edge_src": sds((e,), i32),
                     "edge_dst": sds((e,), i32),
                     "node_mask": sds((n,), jnp.bool_)}
            if cfg.task == "node_reg":
                specs["targets"] = sds((n, cfg.n_out), f32)
                specs["labels"] = sds((n,), i32)
                specs["loss_mask"] = sds((n,), jnp.bool_)
            elif sp.kind == "gnn_molecule":
                specs["labels"] = sds((d["n_graphs"],), i32)
                specs["loss_mask"] = sds((d["n_graphs"],), jnp.bool_)
                specs["graph_ids"] = sds((n,), i32)
            else:
                specs["labels"] = sds((n,), i32)
                specs["loss_mask"] = sds((n,), jnp.bool_)
            if cfg.kind == "egnn":
                specs["pos"] = sds((n, 3), f32)
            if cfg.kind == "mgn":
                specs["edge_attr"] = sds((e, cfg.d_edge), f32)
            return specs
        if fam == "recsys":
            b = d["batch"]
            base = {"hist": sds((b, cfg.seq_len), i32),
                    "target": sds((b,), i32),
                    "user_feats": sds((b, cfg.user_feat_len), i32)}
            if sp.kind == "rec_train":
                base["label"] = sds((b,), f32)
            if sp.kind == "rec_retrieval":
                base = {"hist": sds((1, cfg.seq_len), i32),
                        "user_feats": sds((1, cfg.user_feat_len), i32),
                        "cand_ids": sds((pad512(d["n_candidates"]),), i32)}
            return base
        if fam == "benu":
            if sp.kind == "sbenu_enum":
                n1, D, Dd = d["n_vertices"] + 1, d["row_width"], \
                    d["delta_width"]
                specs = {k: sds((n1, D), i32)
                         for k in ("prev_out", "prev_in",
                                   "cur_out", "cur_in")}
                specs.update({k: sds((n1, Dd), i32)
                              for k in ("delta_out", "delta_out_sign",
                                        "delta_in", "delta_in_sign")})
                specs["starts"] = sds((d["batch"],), i32)
                specs["starts_valid"] = sds((d["batch"],), jnp.bool_)
                return specs
            S = d["n_shards"]
            return {
                "shards": sds((S, d["rows_per_shard"], d["row_width"]), i32),
                "hot_rows": sds((d["hot"] + 1, d["row_width"]), i32),
                "starts": sds((S * d["batch_per_shard"],), i32),
                "starts_valid": sds((S * d["batch_per_shard"],), jnp.bool_),
            }
        raise KeyError(fam)

    # ------------------------------------------------------ per-shape config
    def model_cfg_for(self, shape_name: str):
        """GNN configs vary with the shape (feature dim / classes / task)."""
        if self.family != "gnn":
            return self.model_cfg
        sp = self.shapes[shape_name]
        cfg = self.model_cfg
        if cfg.task == "node_reg":                      # meshgraphnet
            return replace(cfg, d_feat=sp.dims["d_feat"])
        task = "graph_class" if sp.kind == "gnn_molecule" else "node_class"
        return replace(cfg, d_feat=sp.dims["d_feat"],
                       n_out=sp.dims["n_classes"], task=task)

    # ----------------------------------------------------------------- smoke
    def smoke(self) -> "ArchSpec":
        """Reduced same-family config for one-step CPU smoke tests."""
        assert self.smoke_builder is not None, f"{self.name}: no smoke"
        return self.smoke_builder()


# --------------------------------------------------------------------------
# Shared shape sets (the assignment's per-family shape lists)
# --------------------------------------------------------------------------


def lm_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "lm_train",
                              {"seq": 4096, "batch": 256}),
        "prefill_32k": ShapeSpec("prefill_32k", "lm_prefill",
                                 {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeSpec("decode_32k", "lm_decode",
                                {"seq": 32768, "batch": 128}),
        "long_500k": ShapeSpec(
            "long_500k", "lm_long_decode",
            {"seq": 524288, "batch": 1},
            note="decode vs a 512k KV cache; attention is O(L) per emitted "
                 "token — run with sequence-sharded cache + XLA-derived "
                 "flash-decode combine (no sub-quadratic approximation "
                 "needed for decode; see DESIGN.md)"),
    }


def gnn_shapes(d_feat_override: Optional[Dict[str, int]] = None
               ) -> Dict[str, ShapeSpec]:
    ov = d_feat_override or {}
    return {
        "full_graph_sm": ShapeSpec(
            "full_graph_sm", "gnn_full",
            {"n_nodes": 2708, "n_edges": 2 * 10556,
             "d_feat": ov.get("full_graph_sm", 1433), "n_classes": 7},
            note="Cora-scale full batch (edges symmetrized: 2x)"),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg", "gnn_minibatch",
            {"n_nodes": 169_984, "n_edges": 337_920,
             "d_feat": ov.get("minibatch_lg", 602),
             "batch_nodes": 1024, "fanout1": 15, "fanout2": 10,
             "n_classes": 41, "graph_nodes": 232_965},
            note="Reddit-scale sampled block: 1024 targets, fanout 15-10 -> "
                 "padded induced block (nodes 1024*(1+15+150))"),
        "ogb_products": ShapeSpec(
            "ogb_products", "gnn_full",
            {"n_nodes": 2_449_408, "n_edges": 2 * 61_859_140,
             "d_feat": ov.get("ogb_products", 100), "n_classes": 47},
            note="full-batch-large (edges symmetrized; nodes padded 2449029 -> 2449408 for even 1D node sharding)"),
        "molecule": ShapeSpec(
            "molecule", "gnn_molecule",
            {"n_nodes": 128 * 30, "n_edges": 2 * 128 * 64,
             "d_feat": ov.get("molecule", 16), "n_graphs": 128,
             "n_classes": 2},
            note="batched small graphs, block-diagonal"),
    }


def recsys_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "rec_train",
                                 {"batch": 65_536}),
        "serve_p99": ShapeSpec("serve_p99", "rec_serve", {"batch": 512}),
        "serve_bulk": ShapeSpec("serve_bulk", "rec_serve",
                                {"batch": 262_144}),
        "retrieval_cand": ShapeSpec("retrieval_cand", "rec_retrieval",
                                    {"batch": 1,
                                     "n_candidates": 1_000_000}),
    }
