"""benu [paper] — the paper's own technique as a first-class architecture.

Distributed subgraph enumeration of the chordal-square (the core structure
of the paper's hard patterns q7-q9, Table 1) over a production-scale
synthetic power-law graph: 2^27 vertices, padded row width 128, rows
block-partitioned over all 256 (512 multi-pod) devices. The dry-run lowers
one frontier step of the distributed engine (INI -> DBQ(all_to_all) -> INT
-> ENU -> ... -> RES); this is the cell hillclimbed as "most representative
of the paper's technique" in EXPERIMENTS.md §Perf.
"""

from dataclasses import dataclass
from typing import Dict

from .base import ArchSpec, ShapeSpec


@dataclass(frozen=True)
class BenuEnumConfig:
    name: str = "benu"
    pattern: str = "chordal-square"
    n_vertices: int = 1 << 27            # 134M-vertex data graph
    row_width: int = 128                 # padded adjacency width (lanes)
    hot: int = 4096                      # replicated hot rows
    batch_per_shard: int = 4096          # start vertices per device
    req_cap: int = 512                   # all_to_all per-peer budget
    cap_mult: (int, ...) = (8, 16, 16)   # per-ENU capacity x batch
    # S-BENU (streaming) cell
    sbenu_pattern: str = "q1'"           # directed pattern of the delta cell
    sbenu_n_vertices: int = 1 << 24      # 16M-vertex dynamic graph
    delta_width: int = 16                # padded delta adjacency width
    sbenu_batch: int = 8192              # touched start vertices per step


def _shapes(cfg: BenuEnumConfig, n_shards: int) -> Dict[str, ShapeSpec]:
    rps = -(-(cfg.n_vertices + 1) // n_shards)
    return {
        "enum_128m": ShapeSpec(
            "enum_128m", "benu_enum",
            {"n_shards": n_shards, "rows_per_shard": rps,
             "row_width": cfg.row_width, "hot": cfg.hot,
             "batch_per_shard": cfg.batch_per_shard},
            note="one distributed frontier step over the full mesh"),
        "sbenu_delta_16m": ShapeSpec(
            "sbenu_delta_16m", "sbenu_enum",
            {"n_vertices": cfg.sbenu_n_vertices,
             "row_width": cfg.row_width, "delta_width": cfg.delta_width,
             "batch": cfg.sbenu_batch},
            note="one vectorized Delta-P_1 step over the dual snapshot"),
    }


CONFIG = BenuEnumConfig()


def _smoke() -> ArchSpec:
    cfg = BenuEnumConfig(name="benu-smoke", n_vertices=512, row_width=128,
                         hot=16, batch_per_shard=64, req_cap=64,
                         sbenu_n_vertices=512, delta_width=8,
                         sbenu_batch=64)
    return ArchSpec(name="benu/smoke", family="benu", model_cfg=cfg,
                    shapes=_shapes(cfg, n_shards=1))


SPEC = ArchSpec(
    name="benu", family="benu", model_cfg=CONFIG,
    shapes=_shapes(CONFIG, n_shards=256),
    source="this paper",
    applicability="the technique itself",
    smoke_builder=_smoke)
