"""bst [recsys] — Behavior Sequence Transformer, arXiv:1905.06874 (paper).

embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256
interaction=transformer-seq; item table 10^6 rows (row-sharded).
"""

import jax.numpy as jnp

from ..models.bst import BSTConfig
from .base import ArchSpec, ShapeSpec, recsys_shapes

CONFIG = BSTConfig(
    name="bst", n_items=1_000_000, n_user_feats=100_000, user_feat_len=32,
    embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
    mlp_sizes=(1024, 512, 256), dtype=jnp.float32)


def _smoke() -> ArchSpec:
    cfg = BSTConfig(name="bst-smoke", n_items=1000, n_user_feats=500,
                    user_feat_len=8, embed_dim=32, seq_len=20, n_blocks=1,
                    n_heads=8, mlp_sizes=(64, 32))
    return ArchSpec(
        name="bst/smoke", family="recsys", model_cfg=cfg,
        shapes={"train": ShapeSpec("train", "rec_train", {"batch": 16}),
                "retr": ShapeSpec("retr", "rec_retrieval",
                                  {"batch": 1, "n_candidates": 512})})


SPEC = ArchSpec(
    name="bst", family="recsys", model_cfg=CONFIG,
    shapes=recsys_shapes(), source="arXiv:1905.06874; paper",
    applicability=("substrate reuse: the 10^6-row embedding table is "
                   "row-sharded exactly like the BENU DistributedRowStore; "
                   "EmbeddingBag = take + segment_sum per the taxonomy"),
    smoke_builder=_smoke)
