"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434 (hf).

27L d_model=2048 16H, MLA kv_lora=512 (qk_nope=128, qk_rope=64, v=128),
vocab=102400, MoE d_ff=1408, 2 shared + 64 routed top-6, first layer dense
(d_ff=10944).

Assignment-block discrepancy (resolved in DESIGN.md §5): the summary says
"MoE 64e top-6" while the note says "160 routed" — 160 belongs to the full
V2; V2-Lite is 64 routed + 2 shared, which we use.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, ShapeSpec, lm_shapes

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=10944, vocab=102400, rope_theta=10000.0,
    tie_embeddings=False, attn_kind="mla",
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    moe=True, n_experts=64, n_shared=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1, dtype=jnp.bfloat16)


def _smoke() -> ArchSpec:
    cfg = LMConfig(name="dsv2-smoke", n_layers=3, d_model=128, n_heads=4,
                   n_kv_heads=4, d_head=32, d_ff=256, vocab=512,
                   attn_kind="mla", kv_lora_rank=64, qk_nope_dim=32,
                   qk_rope_dim=16, v_head_dim=32,
                   moe=True, n_experts=8, n_shared=2, top_k=2, moe_d_ff=64,
                   first_dense_layers=1, dtype=jnp.float32, remat=False)
    return ArchSpec(
        name="deepseek-v2-lite-16b/smoke", family="lm", model_cfg=cfg,
        shapes={"train": ShapeSpec("train", "lm_train",
                                   {"seq": 32, "batch": 2}),
                "decode": ShapeSpec("decode", "lm_decode",
                                    {"seq": 64, "batch": 2})})


SPEC = ArchSpec(
    name="deepseek-v2-lite-16b", family="lm", model_cfg=CONFIG,
    shapes=lm_shapes(), source="arXiv:2405.04434; hf",
    applicability=("BENU inapplicable; MoE experts sharded over the model "
                   "axis (EP), MLA compressed KV cache in decode"),
    smoke_builder=_smoke)
