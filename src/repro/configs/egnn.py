"""egnn [gnn] — arXiv:2102.09844 (paper tier).

n_layers=4 d_hidden=64 equivariance=E(n): scalar-distance messages +
equivariant coordinate updates.
"""

from ..models.gnn import GNNConfig
from .base import ArchSpec, ShapeSpec, gnn_shapes

CONFIG = GNNConfig(name="egnn", kind="egnn", n_layers=4, d_hidden=64,
                   d_feat=16, n_out=7, task="node_class")


def _smoke() -> ArchSpec:
    cfg = GNNConfig(name="egnn-smoke", kind="egnn", n_layers=2, d_hidden=16,
                    d_feat=8, n_out=3)
    return ArchSpec(
        name="egnn/smoke", family="gnn", model_cfg=cfg,
        shapes={"full": ShapeSpec("full", "gnn_full",
                                  {"n_nodes": 64, "n_edges": 256,
                                   "d_feat": 8, "n_classes": 3})})


SPEC = ArchSpec(
    name="egnn", family="gnn", model_cfg=CONFIG,
    shapes=gnn_shapes(), source="arXiv:2102.09844; paper",
    applicability=("substrate reuse; E(n)-equivariant coordinate updates "
                   "ride the same scatter path"),
    smoke_builder=_smoke)
