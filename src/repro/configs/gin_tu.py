"""gin-tu [gnn] — arXiv:1810.00826 (paper tier).

n_layers=5 d_hidden=64 aggregator=sum eps=learnable. (The TU-dataset GIN;
BatchNorm replaced by LayerNorm for distribution friendliness — DESIGN.md.)
"""

from ..models.gnn import GNNConfig
from .base import ArchSpec, ShapeSpec, gnn_shapes

CONFIG = GNNConfig(name="gin-tu", kind="gin", n_layers=5, d_hidden=64,
                   d_feat=16, n_out=7, task="node_class")


def _smoke() -> ArchSpec:
    cfg = GNNConfig(name="gin-smoke", kind="gin", n_layers=2, d_hidden=16,
                    d_feat=8, n_out=3)
    return ArchSpec(
        name="gin-tu/smoke", family="gnn", model_cfg=cfg,
        shapes={"full": ShapeSpec("full", "gnn_full",
                                  {"n_nodes": 64, "n_edges": 256,
                                   "d_feat": 8, "n_classes": 3}),
                "mol": ShapeSpec("mol", "gnn_molecule",
                                 {"n_nodes": 8 * 10, "n_edges": 2 * 8 * 20,
                                  "d_feat": 8, "n_graphs": 8,
                                  "n_classes": 2})})


SPEC = ArchSpec(
    name="gin-tu", family="gnn", model_cfg=CONFIG,
    shapes=gnn_shapes(), source="arXiv:1810.00826; paper",
    applicability=("substrate reuse; BENU itself ships as a motif-count "
                   "feature extractor for GIN inputs "
                   "(examples/motif_features.py)"),
    smoke_builder=_smoke)
