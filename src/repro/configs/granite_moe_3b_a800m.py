"""granite-moe-3b-a800m [moe] — hf:ibm-granite (assignment block).

32L d_model=1536 24H (GQA kv=8) vocab=49155, MoE 40 experts top-8 with
expert d_ff=512, no shared expert.

Assignment-block discrepancy (resolved in DESIGN.md §5): summary says
"MoE 40e top-8", note says "32 experts top-8" — we use 40 per the summary
line.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, ShapeSpec, lm_shapes

CONFIG = LMConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab=49155, rope_theta=10000.0,
    tie_embeddings=True, attn_kind="gqa",
    moe=True, n_experts=40, n_shared=0, top_k=8, moe_d_ff=512,
    first_dense_layers=0, dtype=jnp.bfloat16)


def _smoke() -> ArchSpec:
    cfg = LMConfig(name="granite-smoke", n_layers=2, d_model=128, n_heads=4,
                   n_kv_heads=2, d_head=32, d_ff=64, vocab=512,
                   tie_embeddings=True, moe=True, n_experts=5, n_shared=0,
                   top_k=2, moe_d_ff=64, dtype=jnp.float32, remat=False)
    return ArchSpec(
        name="granite-moe-3b-a800m/smoke", family="lm", model_cfg=cfg,
        shapes={"train": ShapeSpec("train", "lm_train",
                                   {"seq": 32, "batch": 2}),
                "decode": ShapeSpec("decode", "lm_decode",
                                    {"seq": 64, "batch": 2})})


SPEC = ArchSpec(
    name="granite-moe-3b-a800m", family="lm", model_cfg=CONFIG,
    shapes=lm_shapes(), source="hf:ibm-granite/granite-3.0 family",
    applicability="BENU inapplicable; EP over the model axis",
    smoke_builder=_smoke)
