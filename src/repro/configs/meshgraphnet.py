"""meshgraphnet [gnn] — arXiv:2010.03409 (unverified tier).

n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2; encode-process-decode
with edge features (d_edge=4: relative displacement + norm) and 3-dim node
regression targets.
"""

from ..models.gnn import GNNConfig
from .base import ArchSpec, ShapeSpec, gnn_shapes

CONFIG = GNNConfig(name="meshgraphnet", kind="mgn", n_layers=15,
                   d_hidden=128, d_feat=16, n_out=3, task="node_reg",
                   d_edge=4)


def _smoke() -> ArchSpec:
    cfg = GNNConfig(name="mgn-smoke", kind="mgn", n_layers=3, d_hidden=32,
                    d_feat=8, n_out=3, task="node_reg", d_edge=4)
    return ArchSpec(
        name="meshgraphnet/smoke", family="gnn", model_cfg=cfg,
        shapes={"full": ShapeSpec("full", "gnn_full",
                                  {"n_nodes": 64, "n_edges": 256,
                                   "d_feat": 8, "n_classes": 3})})


SPEC = ArchSpec(
    name="meshgraphnet", family="gnn", model_cfg=CONFIG,
    shapes=gnn_shapes(), source="arXiv:2010.03409; unverified",
    applicability=("direct substrate reuse: the segment_sum edge->node "
                   "scatter and the sharded row gather are the same "
                   "primitives BENU's DBQ/rowstore uses"),
    smoke_builder=_smoke)
