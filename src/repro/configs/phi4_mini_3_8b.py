"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (hf).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, ShapeSpec, lm_shapes

CONFIG = LMConfig(
    name="phi4-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=200064, qkv_bias=False, rope_theta=10000.0,
    tie_embeddings=True, attn_kind="gqa", dtype=jnp.bfloat16)


def _smoke() -> ArchSpec:
    cfg = LMConfig(name="phi4-mini-smoke", n_layers=2, d_model=128,
                   n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=512,
                   tie_embeddings=True, dtype=jnp.float32, remat=False)
    return ArchSpec(
        name="phi4-mini-3.8b/smoke", family="lm", model_cfg=cfg,
        shapes={"train": ShapeSpec("train", "lm_train",
                                   {"seq": 32, "batch": 2}),
                "decode": ShapeSpec("decode", "lm_decode",
                                    {"seq": 64, "batch": 2})})


SPEC = ArchSpec(
    name="phi4-mini-3.8b", family="lm", model_cfg=CONFIG,
    shapes=lm_shapes(), source="arXiv:2412.08905; hf",
    applicability=("BENU inapplicable (no graph-structured data access); "
                   "standard pjit sharding, no technique integration"),
    smoke_builder=_smoke)
