"""pna [gnn] — arXiv:2004.05718 (paper tier).

n_layers=4 d_hidden=75 aggregators=mean-max-min-std
scalers=identity-amplification-attenuation.
"""

from ..models.gnn import GNNConfig
from .base import ArchSpec, ShapeSpec, gnn_shapes

CONFIG = GNNConfig(name="pna", kind="pna", n_layers=4, d_hidden=75,
                   d_feat=16, n_out=7, task="node_class")


def _smoke() -> ArchSpec:
    cfg = GNNConfig(name="pna-smoke", kind="pna", n_layers=2, d_hidden=16,
                    d_feat=8, n_out=3)
    return ArchSpec(
        name="pna/smoke", family="gnn", model_cfg=cfg,
        shapes={"full": ShapeSpec("full", "gnn_full",
                                  {"n_nodes": 64, "n_edges": 256,
                                   "d_feat": 8, "n_classes": 3})})


SPEC = ArchSpec(
    name="pna", family="gnn", model_cfg=CONFIG,
    shapes=gnn_shapes(), source="arXiv:2004.05718; paper",
    applicability="substrate reuse (segment reductions x 4 aggregators)",
    smoke_builder=_smoke)
