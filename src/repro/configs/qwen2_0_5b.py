"""qwen2-0.5b [dense] — arXiv:2407.10671 (hf).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA, QKV bias.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, ShapeSpec, lm_shapes

CONFIG = LMConfig(
    name="qwen2-0.5b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True, attn_kind="gqa", dtype=jnp.bfloat16)


def _smoke() -> ArchSpec:
    cfg = LMConfig(name="qwen2-smoke", n_layers=2, d_model=112, n_heads=7,
                   n_kv_heads=1, d_head=16, d_ff=224, vocab=512,
                   qkv_bias=True, tie_embeddings=True, dtype=jnp.float32,
                   remat=False)
    return ArchSpec(
        name="qwen2-0.5b/smoke", family="lm", model_cfg=cfg,
        shapes={"train": ShapeSpec("train", "lm_train",
                                   {"seq": 32, "batch": 2}),
                "decode": ShapeSpec("decode", "lm_decode",
                                    {"seq": 64, "batch": 2})})


SPEC = ArchSpec(
    name="qwen2-0.5b", family="lm", model_cfg=CONFIG,
    shapes=lm_shapes(), source="arXiv:2407.10671; hf",
    applicability="BENU inapplicable; standard pjit sharding",
    smoke_builder=_smoke)
