"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5-3B (assignment cites the family
card hf:Qwen/Qwen2.5-0.5B).

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936 — GQA, QKV bias.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import ArchSpec, ShapeSpec, lm_shapes

CONFIG = LMConfig(
    name="qwen2.5-3b",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_head=128,
    d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True, attn_kind="gqa", dtype=jnp.bfloat16)


def _smoke() -> ArchSpec:
    cfg = LMConfig(name="qwen2.5-smoke", n_layers=3, d_model=128, n_heads=4,
                   n_kv_heads=2, d_head=32, d_ff=352, vocab=512,
                   qkv_bias=True, tie_embeddings=True, dtype=jnp.float32,
                   remat=False)
    return ArchSpec(
        name="qwen2.5-3b/smoke", family="lm", model_cfg=cfg,
        shapes={"train": ShapeSpec("train", "lm_train",
                                   {"seq": 32, "batch": 2}),
                "decode": ShapeSpec("decode", "lm_decode",
                                    {"seq": 64, "batch": 2})})


SPEC = ArchSpec(
    name="qwen2.5-3b", family="lm", model_cfg=CONFIG,
    shapes=lm_shapes(), source="hf:Qwen/Qwen2.5-3B",
    applicability="BENU inapplicable; standard pjit sharding",
    smoke_builder=_smoke)
