"""BFS-style distributed join baseline (the paper's competitor family).

Left-deep edge join (TwinTwig/SEED/CBF all specialize this skeleton): grow
partial-match tables one pattern edge at a time; every join step in a
distributed dataflow engine must SHUFFLE the partial-match table across the
cluster (hash repartition on the join key). We execute the join in numpy
and *meter* that shuffle: ``bytes_shuffled`` accumulates the byte size of
every intermediate table — the quantity BENU's on-demand design avoids
(Tables 5-6's communication column).

The join is exact (validated against brute force / BENU counts in tests),
so benchmarks/vs_join.py compares both wall time and communication volume
on the same inputs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..graph.storage import Graph
from .pattern import Pattern
from .symmetry import symmetry_breaking_constraints


@dataclass
class JoinStats:
    matches: int
    bytes_shuffled: int
    max_intermediate_rows: int
    steps: List[Tuple[str, int]]          # (edge, rows after join)


def _edge_join_order(pattern: Pattern) -> List[Tuple[int, int]]:
    """Order pattern edges so each one shares a vertex with the prefix."""
    edges = list(pattern.undirected_edges)
    # start from the highest-degree edge (most selective joins first)
    edges.sort(key=lambda e: -(pattern.degree(e[0]) + pattern.degree(e[1])))
    out = [edges.pop(0)]
    placed = set(out[0])
    while edges:
        for i, e in enumerate(edges):
            if e[0] in placed or e[1] in placed:
                out.append(edges.pop(i))
                placed.update(e)
                break
        else:                              # disconnected remainder
            out.append(edges.pop(0))
            placed.update(out[-1])
    return out


def enumerate_join(pattern: Pattern, graph: Graph,
                   constraints: Optional[Sequence[Tuple[int, int]]] = None
                   ) -> JoinStats:
    if constraints is None:
        constraints = symmetry_breaking_constraints(pattern)
    cons = list(constraints)
    n = graph.n
    # CSR adjacency
    indptr = np.zeros(n + 1, np.int64)
    for v in range(n):
        indptr[v + 1] = indptr[v] + len(graph.adj[v])
    nbrs = np.concatenate([graph.adj[v] for v in range(n)]) \
        if n else np.zeros(0, np.int64)
    deg = graph.deg
    edge_keys = set()
    for v in range(n):
        for w in graph.adj[v]:
            edge_keys.add(v * n + int(w))
    edge_key_arr = np.fromiter(edge_keys, dtype=np.int64,
                               count=len(edge_keys))

    order = _edge_join_order(pattern)
    cols: Dict[int, int] = {}              # pattern vertex -> column index
    pm = np.zeros((0, 0), np.int64)
    stats = JoinStats(matches=0, bytes_shuffled=0,
                      max_intermediate_rows=0, steps=[])

    def apply_constraints(pm: np.ndarray, newly: int) -> np.ndarray:
        keep = np.ones(len(pm), bool)
        cn = cols[newly]
        for a, b in cons:
            if a == newly and b in cols:
                keep &= pm[:, cn] < pm[:, cols[b]]
            elif b == newly and a in cols:
                keep &= pm[:, cols[a]] < pm[:, cn]
        # injectivity vs all mapped vertices
        for u, cu in cols.items():
            if u != newly:
                keep &= pm[:, cu] != pm[:, cn]
        return pm[keep]

    first = True
    for (a, b) in order:
        if first:
            src = np.repeat(np.arange(n, dtype=np.int64), deg)
            pm = np.stack([src, nbrs], axis=1)     # both directions
            cols = {a: 0, b: 1}
            pm = apply_constraints(pm, b)
            pm = apply_constraints(pm, a)
            first = False
        elif a in cols and b in cols:
            keys = pm[:, cols[a]] * n + pm[:, cols[b]]
            pm = pm[np.isin(keys, edge_key_arr)]
        else:
            have, new = (a, b) if a in cols else (b, a)
            hv = pm[:, cols[have]]
            counts = deg[hv]
            rep = np.repeat(np.arange(len(pm)), counts)
            starts = indptr[hv]
            # neighbor expansion: offsets within each row's adjacency
            total = int(counts.sum())
            offs = np.arange(total) - np.repeat(
                np.cumsum(counts) - counts, counts)
            new_vals = nbrs[np.repeat(starts, counts) + offs]
            pm = np.concatenate([pm[rep], new_vals[:, None]], axis=1)
            cols = dict(cols)
            cols[new] = pm.shape[1] - 1
            pm = apply_constraints(pm, new)
        stats.bytes_shuffled += pm.nbytes      # hash repartition per join
        stats.max_intermediate_rows = max(stats.max_intermediate_rows,
                                          len(pm))
        stats.steps.append((f"({a},{b})", len(pm)))
    stats.matches = len(pm)
    return stats
