"""Distributed BENU: shard_map SPMD execution over a device mesh.

The paper's deployment (Fig. 7) is: data graph in a distributed KV store;
local search tasks fanned out over workers; tasks query the store on demand.
The TPU mapping:

    worker machine      -> mesh device (one shard of the enumeration axis)
    HBase region        -> block of DistributedRowStore rows in device HBM
    task queue          -> start-vertex range owned by the shard
    on-demand DBQ       -> batched all_to_all request/response
                           (see distributed/rowstore.py)
    LRU DB cache        -> per-level id dedup + replicated hot rows
    task splitting      -> fixed frontier capacities + overflow retries
    skew / stragglers   -> opt-in frontier **rebalancing**: after each ENU
                           the compacted child frontier is striped
                           round-robin over the axis with one all_to_all —
                           per-device load equalizes to ±S rows. The bytes
                           moved are bounded by cap x row-width, exactly the
                           paper's bounded subtask shuffle (§6.3), never
                           proportional to total matches.

All devices run the *same static instruction schedule* (lockstep SPMD), so
collectives are trivially congruent — there is no data-dependent control
flow anywhere in the compiled program.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.rowstore import (RowStoreSpec, build_row_shards,
                                    make_distributed_fetch)
from ..graph.storage import Graph
from .engine_jax import build_enumerator, check_jit_supported, default_caps
from .instructions import ENU, Plan


def enumeration_mesh(axis: str = "shard",
                     devices: Optional[Sequence] = None) -> Mesh:
    """Flat 1-D mesh over all (or given) devices for the enumeration axis."""
    devs = np.array(devices if devices is not None else jax.devices())
    return Mesh(devs, (axis,))


@dataclass
class DistEnumStats:
    count: int
    per_shard_counts: np.ndarray
    per_shard_level_sizes: np.ndarray      # [levels, S]
    cold_rows_fetched: int                 # distinct rows that crossed wire
    request_drops: int
    overflow: int
    chunks_retried: int


def _rebalancer(axis: str, n_shards: int):
    """Round-robin stripe exchange: child i -> shard (i mod S)."""

    def post_expand(env: Dict, valid: jax.Array):
        cap = valid.shape[0]
        assert cap % n_shards == 0, "cap must be divisible by mesh size"
        w = cap // n_shards

        def shuf(x: jax.Array) -> jax.Array:
            # true round-robin: child i -> shard (i mod S); a compacted
            # (valid-first) frontier therefore spreads evenly
            xs = x.reshape((w, n_shards) + x.shape[1:]).swapaxes(0, 1)
            xs = jax.lax.all_to_all(xs, axis, split_axis=0, concat_axis=0,
                                    tiled=False)
            return xs.swapaxes(0, 1).reshape((cap,) + x.shape[1:])

        env2 = {k: shuf(v) for k, v in env.items()}
        return env2, shuf(valid)

    return post_expand


def build_distributed_step(plan: Plan,
                           spec: RowStoreSpec,
                           mesh: Mesh,
                           axis: str,
                           caps: Sequence[int],
                           req_cap: int,
                           rebalance: bool = False,
                           intersect_impl: str = "auto",
                           compaction: str = "cumsum"):
    """shard_map'd enumeration step.

    Returns ``step(shards, hot_rows, starts, starts_valid[, uni]) ->
    (counts[S], overflow[S], cold[S], drops[S], levels[L, S])``.

    ``shards``: int32[S, rps, D] sharded over ``axis``; ``hot_rows``
    replicated; ``starts``/``starts_valid``: [S*B] sharded. This function is
    what the multi-pod dry-run lowers for the paper's own technique.
    """
    has_universe = check_jit_supported(plan)
    S = spec.n_shards
    n_levels = sum(1 for ins in plan.instrs if ins.op == ENU)

    def local_fn(shards, hot_rows, starts, starts_valid, uni=None):
        local_shard = shards[0]            # [rps, D]
        dist_fetch = make_distributed_fetch(spec, axis, req_cap)
        fetch_stats: List[Tuple[jax.Array, jax.Array]] = []

        def fetch(ids: jax.Array) -> jax.Array:
            rows, n_cold, drops = dist_fetch(ids, local_shard, hot_rows)
            fetch_stats.append((n_cold, drops))
            return rows

        post = _rebalancer(axis, S) if rebalance else None
        run = build_enumerator(plan, spec.n, caps, fetch,
                               intersect_impl=intersect_impl,
                               post_expand=post, compaction=compaction)
        if has_universe:
            res = run(starts, starts_valid, uni)
        else:
            res = run(starts, starts_valid)
        cold = sum((c for c, _ in fetch_stats), jnp.zeros((), jnp.int32))
        drops = sum((d for _, d in fetch_stats), jnp.zeros((), jnp.int32))
        levels = (jnp.stack(res.level_sizes)[:, None]
                  if res.level_sizes else jnp.zeros((0, 1), jnp.int32))
        return (res.count[None], res.overflow[None], cold[None],
                drops[None], levels)

    in_specs = [P(axis, None, None), P(None, None), P(axis), P(axis)]
    out_specs = (P(axis), P(axis), P(axis), P(axis), P(None, axis))
    if has_universe:
        in_specs.append(P(None))
    fn = jax.shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def enumerate_distributed(plan: Plan, graph: Graph,
                          mesh: Optional[Mesh] = None,
                          axis: str = "shard",
                          batch_per_shard: int = 64,
                          caps: Optional[Sequence[int]] = None,
                          req_cap: Optional[int] = None,
                          hot: int = 0,
                          rebalance: bool = False,
                          universe_chunk: int = 1024,
                          intersect_impl: str = "auto",
                          max_retries: int = 6) -> DistEnumStats:
    """Enumerate ``plan`` over ``graph`` on every device of ``mesh``.

    Exact (overflow/drops trigger capacity-doubling retries). The
    communication cost surfaced in ``cold_rows_fetched`` is the paper's
    "network communication cost" metric for Fig. 10-style experiments.
    """
    if mesh is None:
        mesh = enumeration_mesh(axis)
    S = mesh.devices.size
    shards_np, hot_np, spec = build_row_shards(graph, S, hot=hot)
    caps0 = list(caps) if caps is not None else default_caps(
        plan, batch_per_shard, spec.d)
    # caps divisible by S for the rebalancer stripes
    caps0 = [-(-c // S) * S for c in caps0]
    rc = req_cap if req_cap is not None else max(
        64, 2 * batch_per_shard // S)
    has_universe = check_jit_supported(plan)

    with jax.default_device(jax.devices()[0]):
        shards = jax.device_put(
            shards_np, jax.NamedSharding(mesh, P(axis, None, None)))
        hot_rows = jax.device_put(
            hot_np, jax.NamedSharding(mesh, P(None, None)))

    if has_universe:
        w = min(universe_chunk, max(graph.n, 1))
        uni_chunks = []
        for u0 in range(0, graph.n, w):
            chunk = np.full(w, graph.n, np.int32)
            hi = min(u0 + w, graph.n)
            chunk[:hi - u0] = np.arange(u0, hi, dtype=np.int32)
            uni_chunks.append(jax.device_put(
                jnp.asarray(chunk), jax.NamedSharding(mesh, P(None))))
    else:
        uni_chunks = [None]

    steps: Dict[Tuple[Tuple[int, ...], int], Callable] = {}

    def get_step(c: Tuple[int, ...], r: int):
        key = (c, r)
        if key not in steps:
            steps[key] = build_distributed_step(
                plan, spec, mesh, axis, c, r, rebalance=rebalance,
                intersect_impl=intersect_impl)
        return steps[key]

    gbatch = S * batch_per_shard
    total = 0
    retried = 0
    tot_cold = 0
    tot_drops_seen = 0
    per_shard = np.zeros(S, np.int64)
    level_acc: Optional[np.ndarray] = None
    for s0 in range(0, graph.n, gbatch):
        ids = np.arange(s0, s0 + gbatch, dtype=np.int32)
        svalid = ids < graph.n
        ids = np.where(svalid, ids, graph.n)
        sharding = jax.NamedSharding(mesh, P(axis))
        args = [shards, hot_rows,
                jax.device_put(jnp.asarray(ids), sharding),
                jax.device_put(jnp.asarray(svalid), sharding)]
        for uni in uni_chunks:
            c, r = tuple(caps0), rc
            a = args + ([uni] if uni is not None else [])
            for _ in range(max_retries + 1):
                counts, overflow, cold, drops, levels = get_step(c, r)(*a)
                ov = int(np.sum(overflow))
                dr = int(np.sum(drops))
                if ov == 0 and dr == 0:
                    break
                retried += 1
                if ov:
                    c = tuple(x * 2 for x in c)
                if dr:
                    r = r * 2
                tot_drops_seen += dr
            else:  # pragma: no cover
                raise RuntimeError("chunk overflowed after retries")
            total += int(np.sum(np.asarray(counts, dtype=np.int64)))
            per_shard += np.asarray(counts, dtype=np.int64)
            tot_cold += int(np.sum(cold))
            lv = np.asarray(levels)
            level_acc = lv if level_acc is None else level_acc + lv
    return DistEnumStats(
        count=total, per_shard_counts=per_shard,
        per_shard_level_sizes=(level_acc if level_acc is not None
                               else np.zeros((0, S))),
        cold_rows_fetched=tot_cold, request_drops=tot_drops_seen,
        overflow=0, chunks_retried=retried)
