"""Distributed BENU: shard_map SPMD execution over a device mesh.

The paper's deployment (Fig. 7) is: data graph in a distributed KV store;
local search tasks fanned out over workers; tasks query the store on demand.
The TPU mapping:

    worker machine      -> mesh device (one shard of the enumeration axis)
    HBase region        -> block of DistributedRowStore rows in device HBM
    task queue          -> start-vertex range owned by the shard
    on-demand DBQ       -> batched all_to_all request/response
                           (see distributed/rowstore.py)
    LRU DB cache        -> per-level id dedup + replicated hot rows
    task splitting      -> fixed frontier capacities + overflow retries
    skew / stragglers   -> opt-in frontier **rebalancing**: after each ENU
                           the compacted child frontier is striped
                           round-robin over the axis with one all_to_all —
                           per-device load equalizes to ±S rows. The bytes
                           moved are bounded by cap x row-width, exactly the
                           paper's bounded subtask shuffle (§6.3), never
                           proportional to total matches.

All devices run the *same static instruction schedule* (lockstep SPMD), so
collectives are trivially congruent — there is no data-dependent control
flow anywhere in the compiled program.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..distributed.rowstore import RowStoreSpec, make_distributed_fetch
from ..graph.storage import Graph
from .engine_jax import build_enumerator, check_jit_supported
from .instructions import ENU, Plan


def enumeration_mesh(axis: str = "shard",
                     devices: Optional[Sequence] = None) -> Mesh:
    """Flat 1-D mesh over all (or given) devices for the enumeration axis."""
    devs = np.array(devices if devices is not None else jax.devices())
    return Mesh(devs, (axis,))


@dataclass
class DistEnumStats:
    count: int
    per_shard_counts: np.ndarray
    per_shard_level_sizes: np.ndarray      # [levels, S]
    cold_rows_fetched: int                 # distinct rows that crossed wire
    request_drops: int
    overflow: int
    chunks_retried: int


def _rebalancer(axis: str, n_shards: int):
    """Round-robin stripe exchange: child i -> shard (i mod S)."""

    def post_expand(env: Dict, valid: jax.Array):
        cap = valid.shape[0]
        assert cap % n_shards == 0, "cap must be divisible by mesh size"
        w = cap // n_shards

        def shuf(x: jax.Array) -> jax.Array:
            # true round-robin: child i -> shard (i mod S); a compacted
            # (valid-first) frontier therefore spreads evenly
            xs = x.reshape((w, n_shards) + x.shape[1:]).swapaxes(0, 1)
            xs = jax.lax.all_to_all(xs, axis, split_axis=0, concat_axis=0,
                                    tiled=False)
            return xs.swapaxes(0, 1).reshape((cap,) + x.shape[1:])

        env2 = {k: shuf(v) for k, v in env.items()}
        return env2, shuf(valid)

    return post_expand


def build_distributed_step(plan: Plan,
                           spec: RowStoreSpec,
                           mesh: Mesh,
                           axis: str,
                           caps: Sequence[int],
                           req_cap: int,
                           rebalance: bool = False,
                           intersect_impl: str = "auto",
                           compaction: str = "cumsum"):
    """shard_map'd enumeration step.

    Returns ``step(shards, hot_rows, starts, starts_valid[, uni]) ->
    (counts[S], overflow[S], cold[S], drops[S], levels[L, S])``.

    ``shards``: int32[S, rps, D] sharded over ``axis``; ``hot_rows``
    replicated; ``starts``/``starts_valid``: [S*B] sharded. This function is
    what the multi-pod dry-run lowers for the paper's own technique.
    """
    has_universe = check_jit_supported(plan)
    S = spec.n_shards
    n_levels = sum(1 for ins in plan.instrs if ins.op == ENU)

    def local_fn(shards, hot_rows, starts, starts_valid, uni=None):
        local_shard = shards[0]            # [rps, D]
        dist_fetch = make_distributed_fetch(spec, axis, req_cap)
        fetch_stats: List[Tuple[jax.Array, jax.Array]] = []

        def fetch(ids: jax.Array) -> jax.Array:
            rows, n_cold, drops = dist_fetch(ids, local_shard, hot_rows)
            fetch_stats.append((n_cold, drops))
            return rows

        post = _rebalancer(axis, S) if rebalance else None
        run = build_enumerator(plan, spec.n, caps, fetch,
                               intersect_impl=intersect_impl,
                               post_expand=post, compaction=compaction)
        if has_universe:
            res = run(starts, starts_valid, uni)
        else:
            res = run(starts, starts_valid)
        cold = sum((c for c, _ in fetch_stats), jnp.zeros((), jnp.int32))
        drops = sum((d for _, d in fetch_stats), jnp.zeros((), jnp.int32))
        levels = (jnp.stack(res.level_sizes)[:, None]
                  if res.level_sizes else jnp.zeros((0, 1), jnp.int32))
        return (res.count[None], res.overflow[None], cold[None],
                drops[None], levels)

    in_specs = [P(axis, None, None), P(None, None), P(axis), P(axis)]
    out_specs = (P(axis), P(axis), P(axis), P(axis), P(None, axis))
    if has_universe:
        in_specs.append(P(None))
    fn = shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def enumerate_distributed(plan: Plan, graph: Graph,
                          mesh: Optional[Mesh] = None,
                          axis: str = "shard",
                          batch_per_shard: int = 64,
                          caps: Optional[Sequence[int]] = None,
                          req_cap: Optional[int] = None,
                          hot: int = 0,
                          rebalance: bool = False,
                          universe_chunk: int = 1024,
                          intersect_impl: str = "auto",
                          max_retries: int = 6,
                          adaptive_split: bool = True) -> DistEnumStats:
    """Enumerate ``plan`` over ``graph`` on every device of ``mesh``.

    Thin wrapper over the unified Executor API (core/executor.py): the
    shared adaptive driver re-chunks overflowing global batches (keeping
    shard-divisible shapes) before escalating capacities / request
    budgets — exact in all cases. ``cold_rows_fetched`` is the paper's
    "network communication cost" metric for Fig. 10-style experiments.
    """
    from .executor import DistBackend, ExecutorConfig, drive
    if mesh is None:
        mesh = enumeration_mesh(axis)
    S = mesh.devices.size
    backend = DistBackend(mesh=mesh, axis=axis, hot=hot,
                          rebalance=rebalance, req_cap=req_cap)
    cfg = ExecutorConfig(batch=S * batch_per_shard, caps=caps,
                         universe_chunk=universe_chunk,
                         intersect_impl=intersect_impl,
                         max_retries=max_retries,
                         adaptive_split=adaptive_split)
    st = drive(backend, plan, graph, cfg)
    return DistEnumStats(
        count=st.count,
        per_shard_counts=st.extras["per_shard_counts"],
        per_shard_level_sizes=st.extras["per_shard_level_sizes"],
        cold_rows_fetched=st.extras["cold_rows_fetched"],
        request_drops=st.drops_seen,
        overflow=0, chunks_retried=st.chunks_retried + st.chunks_split)
