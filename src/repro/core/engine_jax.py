"""Vectorized (TPU-native) executor for BENU execution plans.

The paper's runtime is a MIMD task pool: one backtracking DFS per start
vertex. A TPU pod is a lockstep SPMD machine, so we re-express Algorithm 1's
recursion as **level-synchronous frontier expansion**: a frontier is a batch
of partial matches (one row per partial match); every instruction of the
execution plan acts on the whole frontier at once:

    INI   materialize the start-vertex column
    DBQ   gather adjacency rows for a frontier column     (the on-demand
          shuffle: local gather here; all_to_all in engine_dist)
    INT   row-wise padded-set intersection (Pallas kernel on TPU)
    TRC   semantically identical to INT under SPMD static shapes — the
          memoization win of the paper's per-task dict cache shows up as
          *DBQ dedup* (see engine_dist / unique-based fetch), not as saved
          FLOPs, because a lockstep batch always executes its full shape
    ENU   expand each row by its candidate set and compact valid children
          into a fixed-capacity child frontier (overflow is counted and the
          driver re-chunks; this is the paper's task splitting, vectorized)
    RES   count (or emit) rows that are complete matches

The DFS->BFS change preserves the *set* of matches exactly (instructions are
pure set algebra on a static schedule); only traversal order changes. Every
shape is static, so the program jits, shards, and dry-runs.

Sets are "padded-with-holes" int32 rows: entries == sentinel (= N) are
holes; valid entries ascend. Intersection keeps entries in place, so no
compaction is needed until ENU.
"""

from __future__ import annotations

import functools
from collections import Counter
from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.storage import Graph
from ..kernels import ops as kops
from .instructions import (DBQ, ENU, INI, INT, RES, TRC, Instr, Plan, Var)
from .pattern import Pattern

FetchFn = Callable[[jax.Array], jax.Array]   # ids int32[B] -> rows int32[B,D]


# --------------------------------------------------------------------------
# Device-resident graph
# --------------------------------------------------------------------------


@dataclass
class DeviceGraph:
    """Padded adjacency rows on device. Row ``n`` (sentinel row) is all-holes
    so gathers with invalid ids are safe."""

    rows: jax.Array        # int32[N+1, D]
    n: int                 # number of real vertices; sentinel value

    @property
    def d(self) -> int:
        return self.rows.shape[1]

    @staticmethod
    def from_graph(graph: Graph, d_max: Optional[int] = None,
                   lane: int = 128) -> "DeviceGraph":
        rows, _ = graph.padded_adjacency(d_max=d_max, lane=lane)
        rows = np.concatenate(
            [rows, np.full((1, rows.shape[1]), graph.n, np.int32)], axis=0)
        return DeviceGraph(rows=jnp.asarray(rows), n=graph.n)

    def local_fetch(self) -> FetchFn:
        rows, n = self.rows, self.n

        def fetch(ids: jax.Array) -> jax.Array:
            return rows[jnp.clip(ids, 0, n)]

        return fetch


# --------------------------------------------------------------------------
# Plan preprocessing: liveness + static checks
# --------------------------------------------------------------------------


def _liveness(plan: Plan) -> List[frozenset]:
    """live[i] = vars read at instruction >= i (gathered across ENUs)."""
    live: List[frozenset] = [frozenset()] * (len(plan.instrs) + 1)
    acc: frozenset = frozenset()
    for i in range(len(plan.instrs) - 1, -1, -1):
        acc = acc | frozenset(v for v in plan.instrs[i].uses()
                              if v[0] != "op")
        live[i] = acc
    return live


def classify_fusable_dbqs(plan: Plan) -> FrozenSet[Var]:
    """DBQ targets whose gather can fuse into the intersect kernel.

    A DBQ row set is *fusable* when it is consumed exactly once, by an
    INT or TRC, as a **non-first** operand: the fused kernel
    (kernels/gather_intersect.py) then probes the running result against
    the adjacency rows directly and the ``[B, D]`` gather is never
    materialized. First operands stay materialized (their slots define
    the result layout, keeping fused runs bit-equal to unfused ones), and
    multi-use row sets stay materialized too — re-gathering per consumer
    would move more HBM bytes than the one materialization it saves
    (that reuse is exactly the paper's triangle cache). Used by both the
    engine and ``benchmarks/roofline.py --fused`` so the bytes model and
    the executed program agree.
    """
    use_count: Counter = Counter()
    for ins in plan.instrs:
        use_count.update(ins.uses())
    dbq_targets = {ins.target for ins in plan.instrs if ins.op == DBQ}
    fusable = set()
    for ins in plan.instrs:
        if ins.op == INT:
            consumed = ins.operands[1:]
        elif ins.op == TRC:
            consumed = ins.operands[3:]      # engine folds operands[2] ∩ [3]
        else:
            continue
        for v in consumed:
            if v in dbq_targets and use_count[v] == 1:
                fusable.add(v)
    return frozenset(fusable)


def check_jit_supported(plan: Plan) -> bool:
    """Validate the plan; returns True iff it consumes V(G) (detached-vertex
    matching orders, e.g. the wedge order for the square — the driver then
    additionally iterates universe chunks)."""
    n_vg = 0
    for ins in plan.instrs:
        if ins.op not in (INI, DBQ, INT, TRC, ENU, RES):
            raise NotImplementedError(
                f"engine_jax supports BENU plans only (got {ins.op}); "
                "S-BENU runs through the ref engine / engine_dist extension")
        n_vg += sum(1 for v in ins.operands if v[0] == "VG")
    if n_vg > 1:
        raise NotImplementedError(
            "plans with two detached vertices need nested universe loops; "
            "the best-plan search never emits these")
    return n_vg == 1


# --------------------------------------------------------------------------
# Instruction primitives
# --------------------------------------------------------------------------


def _apply_filters(sets: jax.Array, filters, env: Dict[Var, jax.Array],
                   sentinel: int) -> jax.Array:
    out = sets
    for op, var in filters:
        f = env[var][:, None]
        if op == "<":
            cond = out < f
        elif op == ">":
            cond = out > f
        elif op == "!=":
            cond = out != f
        else:  # pragma: no cover
            raise ValueError(op)
        out = jnp.where(cond, out, sentinel)
    return out


def _expand(env: Dict[Var, jax.Array], valid: jax.Array,
            cand: jax.Array, target: Var, cap: int, live: frozenset,
            sentinel: int, compaction: str = "cumsum",
            extra_cols: Optional[Dict[Var, jax.Array]] = None
            ) -> Tuple[Dict[Var, jax.Array], jax.Array, jax.Array]:
    """ENU: frontier [B] -> child frontier [cap]. Returns (env', valid',
    overflow_count).

    ``extra_cols`` maps extra per-candidate columns (``[B, D]`` aligned with
    ``cand``) to env vars of the child frontier — the S-BENU Delta-ENU uses
    this to carry each candidate's ± snapshot selector alongside its vertex.

    Compaction of the valid children to the front:
      * "cumsum": positions by prefix-sum + one scatter — O(n) HBM traffic.
      * "sort":   stable argsort on the invalid mask — XLA lowers to a
        bitonic network, O(n log^2 n) passes over the buffer. Kept as the
        §Perf baseline; the cumsum path cut the BENU cell's memory term
        ~2.8x (EXPERIMENTS.md).
    Both orders are identical (prefix-sum preserves flat order; the argsort
    was stable), so results are bit-equal.
    """
    B, D = cand.shape
    n = B * D
    flat = cand.reshape(n)
    fvalid = ((cand != sentinel) & valid[:, None]).reshape(n)
    parent = jnp.repeat(jnp.arange(B, dtype=jnp.int32), D)
    if compaction == "sort":
        order = jnp.argsort(~fvalid, stable=True)    # valid rows first
        take = order[:cap]
        new_valid = fvalid[take]
        parents = parent[take]
    else:
        pos = jnp.cumsum(fvalid.astype(jnp.int32)) - 1
        slot = jnp.where(fvalid & (pos < cap), pos, cap)
        take = jnp.full((cap + 1,), n, jnp.int32)
        take = take.at[slot].set(jnp.arange(n, dtype=jnp.int32),
                                 mode="drop")[:cap]
        new_valid = take < n
        take = jnp.where(new_valid, take, 0)
        parents = parent[take]
    total = jnp.sum(fvalid)
    overflow = jnp.maximum(total - jnp.sum(new_valid), 0)
    new_env: Dict[Var, jax.Array] = {}
    for v, arr in env.items():
        if v in live:
            new_env[v] = arr[parents]
    new_env[target] = jnp.where(new_valid, flat[take], sentinel)
    if extra_cols:
        for v, arr in extra_cols.items():
            new_env[v] = jnp.where(new_valid, arr.reshape(n)[take], 0)
    return new_env, new_valid, overflow


def _vcbc_row_counts(plan: Plan, env: Dict[Var, jax.Array],
                     valid: jax.Array, sentinel: int,
                     report: Sequence[Var]) -> jax.Array:
    """Exact per-row match counts for VCBC-compressed plans.

    Non-core vertices are pairwise non-adjacent (V_c is a vertex cover), so
    the plan dropped (a) pairwise injectivity and (b) symmetry order
    constraints between them; we re-impose both here. Closed forms cover
    <= 2 non-core vertices (every paper pattern's compressed plan); more
    requires expansion (ref engine).
    """
    noncore = [v for v in report if v[0] == "C"]
    if len(noncore) > 2:
        raise NotImplementedError(
            f"{len(noncore)} non-core vertices; use the ref engine or a "
            "non-VCBC plan")
    if not noncore:
        return valid.astype(_count_dtype())
    sizes = {v: jnp.sum(env[v] != sentinel, axis=1) for v in noncore}
    if len(noncore) == 1:
        cnt = sizes[noncore[0]]
        return jnp.where(valid, cnt, 0).astype(_count_dtype())
    (va, vb) = noncore
    a, b = env[va], env[vb]
    ua, ub = va[1], vb[1]
    cons = set(plan.constraints)
    pair_valid = (a[:, :, None] != sentinel) & (b[:, None, :] != sentinel)
    if (ua, ub) in cons:
        cond = a[:, :, None] < b[:, None, :]
    elif (ub, ua) in cons:
        cond = a[:, :, None] > b[:, None, :]
    else:
        cond = a[:, :, None] != b[:, None, :]
    cnt = jnp.sum(pair_valid & cond, axis=(1, 2))
    return jnp.where(valid, cnt, 0).astype(_count_dtype())


# --------------------------------------------------------------------------
# Enumerator builder
# --------------------------------------------------------------------------


#: accumulator dtype: int64 when x64 is on (recommended for production —
#: Table-1-scale graphs have >2^31 matches); int32 otherwise, with the
#: driver accumulating cross-chunk totals in Python ints (exact as long as
#: each *chunk* stays below 2^31, guaranteed by the capacity bounds).
def _count_dtype():
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


@dataclass
class EnumResult:
    count: jax.Array                     # scalar: matches in batch
    overflow: jax.Array                  # scalar: dropped children
    level_sizes: Tuple[jax.Array, ...]   # frontier occupancy after each ENU
    matches: Optional[jax.Array] = None  # int32[cap, n] (if collected)
    matches_valid: Optional[jax.Array] = None


jax.tree_util.register_dataclass(
    EnumResult,
    data_fields=["count", "overflow", "level_sizes", "matches",
                 "matches_valid"],
    meta_fields=[])


def build_enumerator(plan: Plan,
                     sentinel: int,
                     caps: Sequence[int],
                     fetch: FetchFn,
                     collect_matches: bool = False,
                     intersect_impl: str = "auto",
                     post_expand: Optional[Callable] = None,
                     compaction: str = "cumsum",
                     fused_rows: Optional[jax.Array] = None,
                     gather_intersect_impl: str = "auto"
                     ) -> Callable[..., EnumResult]:
    """Compile ``plan`` into a jittable function of (starts, starts_valid
    [, universe_chunk]).

    ``caps[i]`` is the child-frontier capacity of the i-th ENU instruction.
    The returned function reports ``overflow`` > 0 when a capacity was hit —
    callers shrink the start batch or raise caps (driver: enumerate_graph).
    Plans consuming V(G) (one detached vertex, e.g. the square's wedge
    order) additionally take ``universe_chunk: int32[W]`` — a sentinel-padded
    slice of V(G); the driver sums counts over chunks. This is the paper's
    |V(G)|/θ subtask split for non-adjacent (u_k1, u_k2), vectorized.

    ``fused_rows`` (the ``[N+1, D]`` device adjacency, row N all-sentinel)
    turns on the fused fetch path: DBQ targets classified by
    :func:`classify_fusable_dbqs` stay *lazy* — the engine carries the
    frontier's id column instead of gathered rows (so ENU re-indexes a
    ``[B]`` column, not a ``[B, D]`` block) and the consuming INT/TRC
    runs ``kops.fused_gather_intersect`` (``gather_intersect_impl``
    selects the kernel; kernels/gather_intersect.py), which never
    materializes the gathered rows. Results are bit-equal to the unfused
    path.
    """
    has_universe = check_jit_supported(plan)
    live = _liveness(plan)
    n_enu = sum(1 for ins in plan.instrs if ins.op == ENU)
    if len(caps) != n_enu:
        raise ValueError(f"need {n_enu} caps, got {len(caps)}")
    if collect_matches and plan.vcbc:
        raise ValueError("cannot collect raw matches from a VCBC plan")

    isect = functools.partial(kops.intersect_padded, sentinel=sentinel,
                              impl=intersect_impl)
    fusable = (classify_fusable_dbqs(plan) if fused_rows is not None
               else frozenset())
    fused = functools.partial(kops.fused_gather_intersect, rows=fused_rows,
                              sentinel=sentinel, impl=gather_intersect_impl)

    def run(starts: jax.Array, starts_valid: jax.Array,
            universe_chunk: Optional[jax.Array] = None) -> EnumResult:
        if has_universe and universe_chunk is None:
            raise ValueError("plan consumes V(G): pass universe_chunk")
        env: Dict[Var, jax.Array] = {}
        lazy: set = set()        # fusable DBQ targets currently holding ids
        valid = starts_valid
        cdt = _count_dtype()
        count = jnp.zeros((), cdt)
        overflow = jnp.zeros((), cdt)
        level_sizes: List[jax.Array] = []
        matches = None
        matches_valid = None
        enu_i = 0
        ip = 0
        while ip < len(plan.instrs):
            ins = plan.instrs[ip]
            if ins.op == INI:
                env[ins.target] = jnp.where(valid, starts, sentinel)
            elif ins.op == DBQ:
                ids = env[ins.operands[0]]
                if ins.target in fusable:
                    # lazy: keep the id column; the consuming INT/TRC
                    # fuses the gather into the intersect kernel
                    env[ins.target] = ids
                    lazy.add(ins.target)
                else:
                    env[ins.target] = fetch(ids)
            elif ins.op in (INT, TRC):
                opvars = (list(ins.operands[2:4]) if ins.op == TRC
                          else list(ins.operands))
                res = None
                for v in opvars:
                    if v[0] == "VG":
                        B = valid.shape[0]
                        s = jnp.broadcast_to(universe_chunk[None, :],
                                             (B, universe_chunk.shape[0]))
                        res = s if res is None else isect(res, s)
                    elif v in lazy:
                        lazy.discard(v)          # single-use by construction
                        # classify_fusable_dbqs only marks non-first
                        # operands lazy (first operands define the result
                        # slots and were materialized at their DBQ), so a
                        # running result always exists here
                        assert res is not None, v
                        res = fused(res, env[v])
                    else:
                        s = env[v]
                        res = s if res is None else isect(res, s)
                if ins.filters:
                    res = _apply_filters(res, ins.filters, env, sentinel)
                env[ins.target] = res
            elif ins.op == ENU:
                cand = env[ins.operands[0]]
                env, valid, ov = _expand(env, valid, cand, ins.target,
                                         caps[enu_i], live[ip + 1], sentinel,
                                         compaction=compaction)
                overflow = overflow + ov.astype(cdt)
                if post_expand is not None:
                    env, valid = post_expand(env, valid)
                level_sizes.append(jnp.sum(valid))
                enu_i += 1
            elif ins.op == RES:
                if plan.vcbc:
                    count = count + jnp.sum(
                        _vcbc_row_counts(plan, env, valid, sentinel,
                                         ins.report)).astype(cdt)
                else:
                    count = count + jnp.sum(valid).astype(cdt)
                    if collect_matches:
                        cols = [env[v] for v in ins.report]
                        matches = jnp.stack(cols, axis=1)
                        matches_valid = valid
            ip += 1
        return EnumResult(count=count, overflow=overflow,
                          level_sizes=tuple(level_sizes),
                          matches=matches, matches_valid=matches_valid)

    return run


# --------------------------------------------------------------------------
# Driver: enumerate a whole graph by start-vertex chunks
# --------------------------------------------------------------------------


def default_caps(plan: Plan, batch: int, d: int,
                 growth: float = 4.0, cap_max: int = 1 << 20) -> List[int]:
    """Heuristic per-level capacities: level0 = batch * d (a start can emit
    up to deg children), then geometric growth clipped to cap_max."""
    n_enu = sum(1 for ins in plan.instrs if ins.op == ENU)
    caps = []
    cur = batch * max(d // 4, 1)
    for _ in range(n_enu):
        caps.append(int(min(max(cur, batch), cap_max)))
        cur *= growth
    return caps


def enumerate_graph(plan: Plan, graph: Graph,
                    batch: int = 256,
                    caps: Optional[Sequence[int]] = None,
                    collect_matches: bool = False,
                    intersect_impl: str = "auto",
                    universe_chunk: int = 1024,
                    max_retries: int = 6,
                    adaptive_split: bool = True) -> Dict[str, object]:
    """Run ``plan`` over every start vertex of ``graph`` on one device.

    Thin wrapper over the unified Executor API (core/executor.py): the
    shared driver re-chunks overflowing start batches (the paper's §5.2
    task splitting, vectorized) and escalates to capacity doubling only
    for single unsplittable chunks — exact in all cases.
    """
    from .executor import ExecutorConfig, JaxBackend, drive
    cfg = ExecutorConfig(batch=batch, caps=caps,
                         collect_matches=collect_matches,
                         intersect_impl=intersect_impl,
                         universe_chunk=universe_chunk,
                         max_retries=max_retries,
                         adaptive_split=adaptive_split)
    st = drive(JaxBackend(), plan, graph, cfg)
    out: Dict[str, object] = {"count": st.count,
                              "chunks_retried": st.chunks_retried
                              + st.chunks_split,
                              "chunks_split": st.chunks_split}
    if collect_matches:
        out["matches"] = st.matches
    return out
