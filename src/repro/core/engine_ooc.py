"""Out-of-core executor: the vectorized engine behind a row cache (§6).

``engine_jax`` compiles a whole BENU plan into one jitted program that
gathers adjacency rows from a device-resident ``[N+1, D]`` matrix — which
caps the data graph at HBM. This module re-expresses the same plan as a
**pull** program, the paper's §6 implementation model vectorized:

* the padded adjacency lives in host-RAM shards
  (:class:`~repro.graph.hoststore.HostRowStore`); device memory holds only
  a bounded row cache (:class:`~repro.distributed.rowcache.DeviceRowCache`:
  pinned hot-by-degree rows + an LRU slab);
* the plan is split into **segments at DBQ boundaries**. Everything
  between two DBQs (INT / TRC / ENU / RES) compiles into one jitted
  function; at each boundary the frontier's id column syncs to host, the
  cache dedups it and gathers only the *cold* rows from the host shards —
  the per-level miss gather. Communication (PCIe here, network in the
  paper) therefore scales with distinct cold rows per level, never with
  partial matches;
* results are bit-identical to ``engine_jax``: the segments run the same
  primitives (`_expand`, `_apply_filters`, `_vcbc_row_counts`) on the
  same schedule, and the cache serves exact rows at any capacity.

The per-level host sync is the price of the pull model; the executor
backend (``core/executor.py``, ``oocache``) hides most of it by
prefetching the next chunk's predicted rows while the current chunk
computes (double-buffered ``device_put``).

Intersections go through :func:`repro.kernels.ops.intersect_padded`, so
the impl follows the shared dispatch registry (explicit
``intersect_impl`` > ``REPRO_INTERSECT_IMPL`` > platform × width default
— kernels/dispatch.py, documented in docs/KERNELS.md). The *fused*
gather+intersect path does not apply here: rows arrive through the host
cache, not a device-resident adjacency, so there is no HBM gather to
fuse away — the cache's per-level dedup plays the equivalent
bytes-saving role on the PCIe boundary.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..distributed.rowcache import DeviceRowCache
from ..kernels import ops as kops
from .instructions import (DBQ, ENU, INI, INT, RES, TRC, Instr, Plan, Var)
from .engine_jax import (_apply_filters, _count_dtype, _expand, _liveness,
                         _vcbc_row_counts, check_jit_supported)

#: one plan segment: (dbq heading the segment or None, [(instr, plan index)],
#: dbq level tag, index of the segment's first ENU within the plan's ENUs)
Segment = Tuple[Optional[Instr], List[Tuple[Instr, int]], int, int]


def split_segments(plan: Plan) -> List[Segment]:
    """Cut ``plan.instrs`` at every DBQ (each cut = one host round-trip)."""
    segs: List[Segment] = []
    head: Optional[Instr] = None
    body: List[Tuple[Instr, int]] = []
    level = -1
    n_levels = 0
    enu_base = 0
    enu_seen = 0
    for ip, ins in enumerate(plan.instrs):
        if ins.op == DBQ:
            segs.append((head, body, level, enu_base))
            head, body = ins, []
            level = n_levels
            n_levels += 1
            enu_base = enu_seen
        else:
            body.append((ins, ip))
            enu_seen += ins.op == ENU
    segs.append((head, body, level, enu_base))
    return segs


class OocEngine:
    """Execute one BENU plan with all row fetches pulled through ``cache``.

    Shapes follow ``engine_jax``: frontiers are ``[B]`` (or ``[cap]``)
    columns of int32 vertex ids (``sentinel = N`` marks holes), adjacency
    sets are ``[B, D]`` padded rows. ``caps[i]`` bounds the i-th ENU's
    child frontier; overflow > 0 invalidates the chunk (the driver
    re-splits it).
    """

    def __init__(self, plan: Plan, cache: DeviceRowCache,
                 collect_matches: bool = False,
                 intersect_impl: str = "auto",
                 compaction: str = "cumsum"):
        import jax
        self.plan = plan
        self.cache = cache
        self.sentinel = cache.n
        self.has_universe = check_jit_supported(plan)
        if collect_matches and plan.vcbc:
            raise ValueError("cannot collect raw matches from a VCBC plan")
        self._collect = collect_matches
        self._intersect = intersect_impl
        self._compaction = compaction
        self._live = _liveness(plan)
        self.segments = split_segments(plan)
        self.n_levels = sum(1 for ins in plan.instrs if ins.op == DBQ)
        self._jit = jax.jit
        # (segment index, B, caps) -> compiled segment
        self._fns: Dict[Tuple[int, int, Tuple[int, ...]], object] = {}

    # ------------------------------------------------------------ segments
    def _seg_fn(self, k: int, B: int, caps: Tuple[int, ...]):
        key = (k, B, caps)
        if key not in self._fns:
            self._fns[key] = self._jit(self._build_seg(k, caps))
        return self._fns[key]

    def _build_seg(self, k: int, caps: Tuple[int, ...]):
        import jax.numpy as jnp
        _, body, _, enu_base = self.segments[k]
        plan, live, sentinel = self.plan, self._live, self.sentinel
        collect = self._collect
        compaction = self._compaction
        isect = functools.partial(kops.intersect_padded, sentinel=sentinel,
                                  impl=self._intersect)

        def seg(env: Dict[Var, object], valid, count, overflow, starts,
                universe_chunk):
            cdt = _count_dtype()
            matches = matches_valid = None
            enu_i = enu_base
            for ins, ip in body:
                if ins.op == INI:
                    env[ins.target] = jnp.where(valid, starts, sentinel)
                elif ins.op in (INT, TRC):
                    if ins.op == TRC:
                        sets = [env[ins.operands[2]], env[ins.operands[3]]]
                    else:
                        sets = []
                        for v in ins.operands:
                            if v[0] == "VG":
                                B = valid.shape[0]
                                sets.append(jnp.broadcast_to(
                                    universe_chunk[None, :],
                                    (B, universe_chunk.shape[0])))
                            else:
                                sets.append(env[v])
                    res = sets[0]
                    for other in sets[1:]:
                        res = isect(res, other)
                    if ins.filters:
                        res = _apply_filters(res, ins.filters, env, sentinel)
                    env[ins.target] = res
                elif ins.op == ENU:
                    cand = env[ins.operands[0]]
                    env, valid, ov = _expand(env, valid, cand, ins.target,
                                             caps[enu_i], live[ip + 1],
                                             sentinel, compaction=compaction)
                    overflow = overflow + ov.astype(cdt)
                    enu_i += 1
                elif ins.op == RES:
                    if plan.vcbc:
                        count = count + jnp.sum(_vcbc_row_counts(
                            plan, env, valid, sentinel,
                            ins.report)).astype(cdt)
                    else:
                        count = count + jnp.sum(valid).astype(cdt)
                        if collect:
                            matches = jnp.stack([env[v] for v in ins.report],
                                                axis=1)
                            matches_valid = valid
            return env, valid, count, overflow, matches, matches_valid

        return seg

    # ----------------------------------------------------------- execution
    def run_chunk(self, starts: np.ndarray, starts_valid: np.ndarray,
                  universe_chunk: Optional[np.ndarray],
                  caps: Sequence[int]):
        """One fixed-shape chunk; returns ``(count, overflow, matches,
        matches_valid)`` as host ints / numpy arrays.

        Each segment boundary costs one device->host sync (the frontier's
        id column) and at most one host->device block (the level's cold
        rows). A chunk whose running overflow turns non-zero aborts early:
        its result would be discarded by the driver anyway, and skipping
        the remaining levels keeps garbage rows out of the cache stats.
        """
        import jax.numpy as jnp
        caps = tuple(int(c) for c in caps)
        starts_j = jnp.asarray(np.asarray(starts, np.int32))
        valid = jnp.asarray(np.asarray(starts_valid, bool))
        uni = (jnp.asarray(universe_chunk) if universe_chunk is not None
               else None)
        if self.has_universe and uni is None:
            raise ValueError("plan consumes V(G): pass universe_chunk")
        cdt = _count_dtype()
        count = jnp.zeros((), cdt)
        overflow = jnp.zeros((), cdt)
        env: Dict[Var, object] = {}
        matches = matches_valid = None
        B = starts_j.shape[0]
        for k, (dbq, _, level, _) in enumerate(self.segments):
            if dbq is not None:
                ids_np = np.asarray(env[dbq.operands[0]])
                env[dbq.target] = self.cache.lookup(ids_np, level=level)
            env, valid, count, overflow, m, mv = self._seg_fn(k, B, caps)(
                env, valid, count, overflow, starts_j, uni)
            if m is not None:
                matches, matches_valid = m, mv
            if k + 1 < len(self.segments) and int(overflow) > 0:
                return 0, int(overflow), None, None
        out_matches = None
        if self._collect and int(overflow) == 0 and matches is not None:
            mnp = np.asarray(matches)
            out_matches = mnp[np.asarray(matches_valid)]
        return int(count), int(overflow), out_matches, matches_valid
