"""Distributed S-BENU: shard_map SPMD delta-frontier enumeration.

``engine_dist`` maps the paper's static deployment (Fig. 7) onto a device
mesh; this module does the same for the *streaming* half (§5, Alg. 4).
The six-block dual snapshot of :mod:`repro.graph.dynamic` is row-block
partitioned over the enumeration axis (owner of vertex v's rows =
``v // rows_per_shard``) exactly the way ``DistBackend`` shards static
adjacency rows, with the ``hot`` highest-id rows of every block
replicated (a hub set when the stream is degree-relabeled; see
``SnapshotShardSpec``):

    worker machine         -> mesh device (one shard of the axis)
    two-form vertex value  -> the shard's rows of all six blocks
                              (prev/cur/delta x out/in), resident across
                              time steps (graph/dynamic.py
                              ShardedDeviceSnapshotStore)
    typed on-demand DBQ    -> batched request/response all_to_all against
                              the owning shard of the addressed block —
                              the paper's distributed KV lookup; the
                              flagged delta row moves as ONE joint
                              (values ++ signs) exchange
    LRU DB cache           -> per-level id dedup + replicated hot rows
    ΔR_t^± result sets     -> per-shard counts (and optionally match
                              rows), reduced across the mesh by the
                              driver
    skew / stragglers      -> the same round-robin frontier rebalancer as
                              the static engine, applied after every
                              Delta-ENU / ENU expansion

Communication happens **only at typed-DBQ boundaries** (plus the opt-in
rebalance shuffle and the final count reduce): frontier expansion, INS
probes, and intersections are shard-local, so bytes moved scale with
distinct cold rows — never with partial matches. All devices run the same
static instruction schedule (lockstep SPMD), so the collectives are
trivially congruent.

The instruction loop itself is :func:`~repro.core.engine_sbenu_jax.
build_sbenu_instr_runner` — identical math to the single-device engine;
only the three gathers behind the typed-DBQ selector differ.
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..distributed.rowstore import make_distributed_fetch
from ..graph.dynamic import SnapshotShardSpec
from .engine_dist import _rebalancer
from .engine_sbenu_jax import (FlaggedRows, _resolve_intersect_impl,
                               _resort_fn, build_sbenu_instr_runner,
                               make_typed_fetch)
from .instructions import Plan

#: positional order of the sharded value blocks / their replicated hot
#: slices in the step signature (matches ShardedDeviceSnapshotStore
#: .step_sharded() keys)
BLOCK_ORDER = ("prev_out", "cur_out", "prev_in", "cur_in",
               "delta_joint_out", "delta_joint_in")


def build_sbenu_dist_step(plans: Sequence[Plan], sentinel: int,
                          spec: SnapshotShardSpec, mesh: Mesh, axis: str,
                          caps_list: Sequence[Sequence[int]], req_cap: int,
                          rebalance: bool = False,
                          collect_matches: bool = False,
                          intersect_impl: str = "auto",
                          compaction: str = "cumsum") -> Callable:
    """shard_map'd streaming enumeration step, all ΔP_i plans fused.

    Returns ``step(*blocks, *hot_blocks, starts, starts_valid)`` (block
    order :data:`BLOCK_ORDER`; ``starts``/``starts_valid``: ``[S*B]``
    sharded over ``axis``) producing per-shard
    ``(count_plus[S], count_minus[S], overflow[S], cold[S], drops[S],
    levels[L, S])`` plus, when ``collect_matches``, the gathered
    ``(matches [S*M, n], match_ops [S*M], matches_valid [S*M])`` where M
    sums the last-level capacities over plans.

    ``caps_list[i]`` are the *per-shard* frontier capacities of plan i;
    with ``rebalance`` they must be divisible by the mesh size (the
    driver's ``cap_multiple`` contract).
    """
    S = spec.n_shards
    post = _rebalancer(axis, S) if rebalance else None
    runners = [build_sbenu_instr_runner(p, sentinel, c,
                                        collect_matches=collect_matches,
                                        intersect_impl=intersect_impl,
                                        compaction=compaction,
                                        post_expand=post)
               for p, c in zip(plans, caps_list)]
    resort = _resort_fn(_resolve_intersect_impl(intersect_impl) == "binary")

    def local_fn(prev_out, cur_out, prev_in, cur_in, dj_out, dj_in,
                 h_prev_out, h_cur_out, h_prev_in, h_cur_in, h_dj_out,
                 h_dj_in, starts, starts_valid):
        row_fetch = make_distributed_fetch(spec, axis, req_cap)
        fetch_stats: List[Tuple[jax.Array, jax.Array]] = []

        def served(local: jax.Array, hot: jax.Array,
                   ids: jax.Array) -> jax.Array:
            rows, n_cold, drops = row_fetch(ids, local, hot)
            fetch_stats.append((n_cold, drops))
            return rows

        prev = {"out": (prev_out, h_prev_out), "in": (prev_in, h_prev_in)}
        cur = {"out": (cur_out, h_cur_out), "in": (cur_in, h_cur_in)}
        dj = {"out": (dj_out, h_dj_out), "in": (dj_in, h_dj_in)}

        def gather_prev(di: str, ids: jax.Array) -> jax.Array:
            return served(*prev[di], ids)

        def gather_cur(di: str, ids: jax.Array) -> jax.Array:
            return served(*cur[di], ids)

        def gather_delta(di: str, ids: jax.Array) -> FlaggedRows:
            joint = served(*dj[di], ids)
            dd = joint.shape[1] // 2
            vals, signs = joint[:, :dd], joint[:, dd:]
            # rows the fetch filled whole (invalid/hot-miss/dropped ids)
            # carry the sentinel in the sign half too; flag holes are 0
            return vals, jnp.where(vals == sentinel, 0, signs)

        fetch = make_typed_fetch(sentinel, resort, gather_prev, gather_cur,
                                 gather_delta)
        rs = [r(fetch, starts, starts_valid) for r in runners]
        cp = sum((r.count_plus for r in rs), jnp.zeros((), jnp.int32))
        cm = sum((r.count_minus for r in rs), jnp.zeros((), jnp.int32))
        ov = sum((r.overflow for r in rs), jnp.zeros((), jnp.int32))
        cold = sum((c for c, _ in fetch_stats), jnp.zeros((), jnp.int32))
        drops = sum((d for _, d in fetch_stats), jnp.zeros((), jnp.int32))
        levels = jnp.stack([s for r in rs for s in r.level_sizes])[:, None]
        outs = (cp[None], cm[None], ov[None], cold[None], drops[None],
                levels)
        if collect_matches:
            outs += (jnp.concatenate([r.matches for r in rs], axis=0),
                     jnp.concatenate([r.match_ops for r in rs], axis=0),
                     jnp.concatenate([r.matches_valid for r in rs], axis=0))
        return outs

    in_specs = (P(axis, None),) * 6 + (P(None, None),) * 6 \
        + (P(axis), P(axis))
    out_specs: Tuple = (P(axis),) * 5 + (P(None, axis),)
    if collect_matches:
        out_specs = out_specs + (P(axis, None), P(axis), P(axis))
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)
