"""Vectorized (JIT) executor for S-BENU incremental execution plans.

``engine_jax`` re-expressed BENU's per-task backtracking as lockstep
frontier expansion; this module does the same for the streaming half of the
paper (§5): every incremental plan ΔP_i becomes a jittable function over a
batch of start vertices (the touched-vertex set of the update batch) and
the six-block device snapshot of :mod:`repro.graph.dynamic`.

What changes relative to the static engine:

    DBQ   takes a (type, direction, op) selector against the dual-snapshot
          store: ``(either, dir, +/-)`` gathers the current/previous block,
          ``unaltered`` masks previous rows lane-wise against the deleted
          delta entries, ``delta`` sign-filters the flagged delta rows.
          ``adj_op='op'`` resolves per row via the snapshot selector bound
          by the Delta-ENU (a ``where`` between the two gathers).
    DENU  Delta-ENU: expands the flagged candidate set like ENU but carries
          each child's ± flag as an extra frontier column — the per-row
          snapshot selector for every later op-dependent DBQ and for the
          ΔR_t^+ / ΔR_t^- classification at RES.
    INS   back-edge existence test: a lane-wise membership probe of the
          mapped vertex against a fetched typed row; failing rows are
          invalidated (the vectorized backtrack).

Flagged sets are value/sign row pairs: values follow the padded-set
convention (sentinel holes, ascending), signs are +1/-1 with 0 at holes.
Every shape is static, so the program jits; the unified Executor driver
(core/executor.py, ``sbenu-jax`` backend) owns chunking and overflow.

The instruction loop is split from the data source: the typed-DBQ selector
is a pluggable ``fetch(ids, type, direction, op, opsign)`` built by
:func:`make_typed_fetch` from three gather callbacks, so the same loop runs
against a resident :class:`DeviceSnapshot` (this module) or against
mesh-sharded blocks served by request/response collectives
(core/engine_sbenu_dist.py).
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from ..graph.dynamic import DeviceSnapshot
from ..kernels import ops as kops
from .instructions import (DBQ, DENU, ENU, INI, INS, INT, RES, Instr, Plan,
                           Var)
from .engine_jax import _apply_filters, _count_dtype, _expand

#: pseudo-variable carrying the per-row snapshot selector (+1 -> G'_t,
#: -1 -> G'_{t-1}); bound by DENU, read by op-dependent DBQs and RES.
OP_VAR: Var = ("op", -1)

jax.tree_util.register_dataclass(
    DeviceSnapshot,
    data_fields=["prev_out", "prev_in", "cur_out", "cur_in",
                 "delta_out", "delta_out_sign", "delta_in", "delta_in_sign"],
    meta_fields=["n"])


def device_put_snapshot(snap: DeviceSnapshot) -> DeviceSnapshot:
    """Move the six blocks to device once per time step (the jitted runner
    then sees committed device arrays instead of re-transferring numpy)."""
    return jax.tree.map(jnp.asarray, snap)


# --------------------------------------------------------------------------
# Plan preprocessing
# --------------------------------------------------------------------------


def check_sbenu_jit_supported(plan: Plan) -> None:
    """Validate that ``plan`` is a connected-order incremental plan."""
    n_denu = 0
    for ins in plan.instrs:
        if ins.op not in (INI, DBQ, INT, ENU, DENU, INS, RES):
            raise NotImplementedError(
                f"engine_sbenu_jax cannot execute {ins.op}")
        if any(v[0] == "VG" for v in ins.operands):
            raise NotImplementedError(
                "incremental plans are rooted at the delta edge and never "
                "consume V(G)")
        n_denu += ins.op == DENU
    if n_denu != 1:
        raise NotImplementedError(
            f"expected exactly one Delta-ENU, got {n_denu}")


def _sbenu_liveness(plan: Plan) -> List[frozenset]:
    """live[i] = vars read at instruction >= i. Unlike the static engine,
    the op pseudo-variable is tracked: RES classifies matches by it."""
    live: List[frozenset] = [frozenset()] * (len(plan.instrs) + 1)
    acc: frozenset = frozenset({OP_VAR})   # RES (last instr) reads it
    for i in range(len(plan.instrs) - 1, -1, -1):
        acc = acc | frozenset(plan.instrs[i].uses())
        live[i] = acc
    return live


def plan_level_count(plan: Plan) -> int:
    """Expansion levels = DENU + ENU instructions (one capacity each)."""
    return sum(1 for ins in plan.instrs if ins.op in (ENU, DENU))


def sbenu_default_caps(plan: Plan, batch: int, d_delta: int = 0,
                       d: int = 0, growth: float = 2.0,
                       cap_max: int = 1 << 20) -> List[int]:
    """Per-level capacities for delta frontiers.

    Unlike the static engine (whose frontiers *fan out* by a degree factor
    per level), delta frontiers stay near the start-batch size: a start
    emits its handful of delta edges, and every later level intersects
    typed adjacency — almost always a contraction. Capacities therefore
    start at ``2 * batch`` and grow gently; the rare heavy step overflows
    and is re-chunked (or capacity-doubled) by the adaptive driver, which
    is far cheaper than paying a worst-case ``batch * d_delta * d`` pad on
    every chunk. ``d_delta``/``d`` only tighten the first level when the
    delta rows are known to be narrow."""
    caps: List[int] = []
    first = 2 * batch
    if d_delta:
        first = min(first, batch * max(d_delta, 1))
    cur = float(max(first, 8))
    for ins in plan.instrs:
        if ins.op in (DENU, ENU):
            caps.append(int(min(max(int(cur), batch), cap_max)))
            cur *= growth
    return caps


def sbenu_level_fanouts(plan: Plan) -> List[bool]:
    """Per expansion level: does it *fan out* (True) or contract (False)?

    A level whose candidate set is built from a single typed adjacency
    (e.g. the 4-cycle's ``C3 := Intersect(AUO2) | >f1``) multiplies the
    frontier by ~avg degree; a level intersecting >= 2 adjacencies almost
    always contracts. The DENU level is always reported as contracting —
    its exact bound (the chunk's delta-edge total) is computed separately.
    """
    from .instructions import SB_ADJ_KINDS
    defs: Dict[Var, Instr] = {}
    for ins in plan.instrs:
        if ins.target is not None:
            defs[ins.target] = ins

    def adj_inputs(var: Var, seen: frozenset) -> set:
        ins = defs.get(var)
        if ins is None or var in seen:
            return set()
        out: set = set()
        for v in ins.operands:
            if v[0] in SB_ADJ_KINDS:
                out.add(v)
            else:
                out |= adj_inputs(v, seen | {var})
        return out

    fan: List[bool] = []
    for ins in plan.instrs:
        if ins.op == DENU:
            fan.append(False)
        elif ins.op == ENU:
            fan.append(len(adj_inputs(ins.operands[0], frozenset())) < 2)
    return fan


def _resolve_intersect_impl(impl: str) -> str:
    """``auto`` -> Pallas on TPU, binary-search elsewhere (delta rows are
    kept ascending precisely so the O(D log D) path applies).

    A thin veneer over :func:`repro.kernels.dispatch.resolve_impl` — the
    one resolution order (explicit impl > ``REPRO_INTERSECT_IMPL`` env
    override > platform default) shared with kernels/ops.py; this module
    only swaps the CPU default from the dense probe to the binary search
    its ascending-row invariant enables (``_resort_fn`` maintains it).
    """
    from ..kernels.dispatch import resolve_impl
    resolved = resolve_impl("intersect", impl)
    env = os.environ.get("REPRO_INTERSECT_IMPL", "").strip()
    # env values "" and the literal "auto" are both non-overrides: in
    # either case resolve_impl fell through to the platform default, and
    # this engine's CPU default is the binary probe, not the dense one
    if impl == "auto" and resolved in ("ref", "chunked") \
            and env in ("", "auto"):
        return "binary"
    return resolved


def _resort_fn(binary: bool) -> Callable[[jax.Array], jax.Array]:
    """The binary-search intersect needs b-side rows fully ascending with
    tail holes; resort() restores that invariant after masking/filtering
    (identity for every other impl — they accept in-place holes)."""
    if binary:
        return lambda rows: jnp.sort(rows, axis=-1)
    return lambda rows: rows


# --------------------------------------------------------------------------
# Enumerator builder
# --------------------------------------------------------------------------


@dataclass
class SBenuEnumResult:
    count_plus: jax.Array                # scalar: ΔR_t^+ matches in batch
    count_minus: jax.Array               # scalar: ΔR_t^- matches in batch
    overflow: jax.Array                  # scalar: dropped children
    level_sizes: Tuple[jax.Array, ...]
    matches: Optional[jax.Array] = None        # int32[cap, n]
    match_ops: Optional[jax.Array] = None      # int32[cap] (+1/-1)
    matches_valid: Optional[jax.Array] = None  # bool[cap]


jax.tree_util.register_dataclass(
    SBenuEnumResult,
    data_fields=["count_plus", "count_minus", "overflow", "level_sizes",
                 "matches", "match_ops", "matches_valid"],
    meta_fields=[])

FlaggedRows = Tuple[jax.Array, jax.Array]       # (values, signs)

#: fetch(ids, type, direction, op, opsign) -> rows | (values, signs)
TypedFetch = Callable[..., Union[jax.Array, FlaggedRows]]


def make_typed_fetch(sentinel: int,
                     resort: Callable[[jax.Array], jax.Array],
                     gather_prev: Callable[[str, jax.Array], jax.Array],
                     gather_cur: Callable[[str, jax.Array], jax.Array],
                     gather_delta: Callable[[str, jax.Array], FlaggedRows],
                     gather_opsel: Optional[Callable] = None) -> TypedFetch:
    """The (type, direction, op) DBQ selector of §5.3.1 over three row
    gathers.

    ``gather_prev``/``gather_cur`` serve G'_{t-1}/G'_t rows for one
    direction; ``gather_delta`` serves the flagged delta (values, signs)
    pair. The lane-wise derivations (``unaltered`` masking, sign
    filtering, the per-row snapshot select) are shared by every engine —
    only the gathers differ (resident block indexing here, request/
    response collectives in the sharded engine). ``gather_opsel`` is an
    optional fast path for the op-dependent select (the resident engine's
    single offset gather over stacked prev/cur); without it the select is
    two gathers + a row-wise ``where``.
    """

    def fetch(ids: jax.Array, ty: str, direction: str, op,
              opsign: Optional[jax.Array]) -> Union[jax.Array, FlaggedRows]:
        if ty == "either":
            if op == "+":
                return gather_cur(direction, ids)
            if op == "-":
                return gather_prev(direction, ids)
            # per-row snapshot selector bound by the Delta-ENU
            if gather_opsel is not None:
                return gather_opsel(direction, ids, opsign)
            pv = gather_prev(direction, ids)
            cv = gather_cur(direction, ids)
            return jnp.where((opsign > 0)[:, None], cv, pv)
        if ty == "unaltered":
            # prev minus deleted: mask prev entries that appear with a
            # '-' flag in the delta row (lane-wise membership probe)
            rows = gather_prev(direction, ids)
            dvals, dsigns = gather_delta(direction, ids)
            deleted = jnp.where(dsigns < 0, dvals, sentinel)
            hit = jnp.any(rows[:, :, None] == deleted[:, None, :], axis=2)
            return resort(jnp.where(hit, sentinel, rows))
        if ty == "delta":
            dvals, dsigns = gather_delta(direction, ids)
            if op == "*":
                return dvals, dsigns
            want = (dsigns > 0) if op == "+" else (dsigns < 0) \
                if op == "-" else (dsigns * opsign[:, None] > 0)
            return resort(jnp.where(want, dvals, sentinel))
        raise ValueError(ty)

    return fetch


def build_sbenu_instr_runner(plan: Plan, sentinel: int, caps: Sequence[int],
                             collect_matches: bool = False,
                             intersect_impl: str = "auto",
                             compaction: str = "cumsum",
                             post_expand: Optional[Callable] = None
                             ) -> Callable[..., SBenuEnumResult]:
    """The incremental instruction loop over a pluggable typed fetch.

    Returns ``run_instrs(fetch, starts, starts_valid)`` where ``fetch`` is
    a :func:`make_typed_fetch` selector. ``post_expand(env, valid)`` (if
    given) runs after every DENU/ENU expansion — the sharded engine's
    frontier rebalancer hook, identical to the static engine's.
    """
    check_sbenu_jit_supported(plan)
    live = _sbenu_liveness(plan)
    n_lv = plan_level_count(plan)
    if len(caps) != n_lv:
        raise ValueError(f"need {n_lv} caps, got {len(caps)}")

    impl = _resolve_intersect_impl(intersect_impl)
    binary = impl == "binary"
    isect = functools.partial(kops.intersect_padded, sentinel=sentinel,
                              impl=impl)
    resort = _resort_fn(binary)

    def run_instrs(fetch: TypedFetch, starts: jax.Array,
                   starts_valid: jax.Array) -> SBenuEnumResult:
        env: Dict[Var, object] = {}
        valid = starts_valid
        cdt = _count_dtype()
        count_plus = jnp.zeros((), cdt)
        count_minus = jnp.zeros((), cdt)
        overflow = jnp.zeros((), cdt)
        level_sizes: List[jax.Array] = []
        matches = match_ops = matches_valid = None
        lv = 0
        for ip, ins in enumerate(plan.instrs):
            if ins.op == INI:
                env[ins.target] = jnp.where(valid, starts, sentinel)
            elif ins.op == DBQ:
                ids = env[ins.operands[0]]
                op = ins.adj_op
                env[ins.target] = fetch(ids, ins.adj_type, ins.adj_dir, op,
                                        env.get(OP_VAR))
            elif ins.op == INT:
                sets = [env[v] for v in ins.operands]
                flagged = [s for s in sets if isinstance(s, tuple)]
                plain = [s for s in sets if not isinstance(s, tuple)]
                if flagged:
                    # the delta candidate set: flag-aware filtering keeps
                    # values and signs aligned (Delta-ENU consumes both)
                    assert len(flagged) == 1
                    vals, signs = flagged[0]
                    for other in plain:
                        vals = isect(vals, other)
                    if ins.filters:
                        vals = _apply_filters(vals, ins.filters, env,
                                              sentinel)
                    signs = jnp.where(vals != sentinel, signs, 0)
                    env[ins.target] = (vals, signs)
                else:
                    res = plain[0]
                    for other in plain[1:]:
                        res = isect(res, other)
                    if ins.filters:
                        res = _apply_filters(res, ins.filters, env, sentinel)
                    env[ins.target] = resort(res)
            elif ins.op in (ENU, DENU):
                extra = None
                if ins.op == DENU:
                    cand, signs = env[ins.operands[0]]
                    extra = {OP_VAR: signs}
                else:
                    cand = env[ins.operands[0]]
                plain_env = {v: a for v, a in env.items()
                             if not isinstance(a, tuple)}
                plain_env, valid, ov = _expand(
                    plain_env, valid, cand, ins.target, caps[lv],
                    live[ip + 1], sentinel, compaction=compaction,
                    extra_cols=extra)
                env = plain_env
                overflow = overflow + ov.astype(cdt)
                if post_expand is not None:
                    env, valid = post_expand(env, valid)
                level_sizes.append(jnp.sum(valid))
                lv += 1
            elif ins.op == INS:
                fv = env[ins.operands[0]]
                rows = env[ins.operands[1]]
                hit = jnp.any(rows == fv[:, None], axis=1)
                valid = valid & hit & (fv != sentinel)
            elif ins.op == RES:
                opsign = env[OP_VAR]
                count_plus = count_plus + jnp.sum(
                    valid & (opsign > 0)).astype(cdt)
                count_minus = count_minus + jnp.sum(
                    valid & (opsign < 0)).astype(cdt)
                if collect_matches:
                    matches = jnp.stack([env[v] for v in ins.report], axis=1)
                    match_ops = opsign
                    matches_valid = valid
        return SBenuEnumResult(count_plus=count_plus,
                               count_minus=count_minus,
                               overflow=overflow,
                               level_sizes=tuple(level_sizes),
                               matches=matches, match_ops=match_ops,
                               matches_valid=matches_valid)

    return run_instrs


def build_sbenu_enumerator(plan: Plan, sentinel: int, caps: Sequence[int],
                           collect_matches: bool = False,
                           intersect_impl: str = "auto",
                           compaction: str = "cumsum"
                           ) -> Callable[..., SBenuEnumResult]:
    """Compile an incremental plan into a jittable function of
    ``(snap: DeviceSnapshot, starts int32[B], starts_valid bool[B])``.

    ``caps[i]`` is the child-frontier capacity of the i-th expansion level
    (DENU or ENU). Overflow reporting follows the static engine: a result
    with ``overflow > 0`` must be discarded and re-chunked by the driver.
    """
    run_instrs = build_sbenu_instr_runner(
        plan, sentinel, caps, collect_matches=collect_matches,
        intersect_impl=intersect_impl, compaction=compaction)
    resort = _resort_fn(_resolve_intersect_impl(intersect_impl) == "binary")

    def run(snap: DeviceSnapshot, starts: jax.Array,
            starts_valid: jax.Array) -> SBenuEnumResult:
        n = snap.n
        assert n == sentinel, "snapshot/plan sentinel mismatch"
        rows_total = snap.prev_out.shape[0]      # n + 1, or mesh-padded
        # prev/cur stacked per direction: the per-row snapshot selector
        # becomes a single offset gather instead of two gathers + where
        # (XLA CSEs the concats across repeated DBQs and fused plans)
        stacked = {"out": jnp.concatenate([snap.prev_out, snap.cur_out],
                                          axis=0),
                   "in": jnp.concatenate([snap.prev_in, snap.cur_in],
                                         axis=0)}
        prev = {"out": snap.prev_out, "in": snap.prev_in}
        cur = {"out": snap.cur_out, "in": snap.cur_in}
        delta = {"out": (snap.delta_out, snap.delta_out_sign),
                 "in": (snap.delta_in, snap.delta_in_sign)}

        def gather(block: jax.Array, ids: jax.Array) -> jax.Array:
            return block[jnp.clip(ids, 0, n)]

        def gather_prev(direction: str, ids: jax.Array) -> jax.Array:
            return gather(prev[direction], ids)

        def gather_cur(direction: str, ids: jax.Array) -> jax.Array:
            return gather(cur[direction], ids)

        def gather_delta(direction: str, ids: jax.Array) -> FlaggedRows:
            dvals, dsigns = delta[direction]
            return gather(dvals, ids), gather(dsigns, ids)

        def gather_opsel(direction: str, ids: jax.Array,
                         opsign: jax.Array) -> jax.Array:
            side = jnp.where(opsign > 0, rows_total, 0)
            return stacked[direction][jnp.clip(ids, 0, n) + side]

        fetch = make_typed_fetch(sentinel, resort, gather_prev, gather_cur,
                                 gather_delta, gather_opsel)
        return run_instrs(fetch, starts, starts_valid)

    return run


def build_sbenu_multi_enumerator(plans: Sequence[Plan], sentinel: int,
                                 caps_list: Sequence[Sequence[int]],
                                 collect_matches: bool = False,
                                 intersect_impl: str = "auto",
                                 compaction: str = "cumsum"
                                 ) -> Callable[..., SBenuEnumResult]:
    """Fuse every incremental plan ΔP_i into ONE jittable function.

    A time step runs all m plans over the same start chunk; dispatching
    them as one XLA program removes m-1 dispatch/sync round-trips per
    chunk and lets XLA CSE the shared snapshot gathers. Counts and
    overflow are summed; collected matches are concatenated (each plan's
    matches are disjoint by Theorem 5).
    """
    runs = [build_sbenu_enumerator(p, sentinel, c,
                                   collect_matches=collect_matches,
                                   intersect_impl=intersect_impl,
                                   compaction=compaction)
            for p, c in zip(plans, caps_list)]

    def run(snap: DeviceSnapshot, starts: jax.Array,
            starts_valid: jax.Array) -> SBenuEnumResult:
        rs = [r(snap, starts, starts_valid) for r in runs]
        matches = match_ops = matches_valid = None
        if collect_matches:
            matches = jnp.concatenate([r.matches for r in rs], axis=0)
            match_ops = jnp.concatenate([r.match_ops for r in rs], axis=0)
            matches_valid = jnp.concatenate([r.matches_valid for r in rs],
                                            axis=0)
        return SBenuEnumResult(
            count_plus=sum(r.count_plus for r in rs),
            count_minus=sum(r.count_minus for r in rs),
            overflow=sum(r.overflow for r in rs),
            level_sizes=tuple(s for r in rs for s in r.level_sizes),
            matches=matches, match_ops=match_ops,
            matches_valid=matches_valid)

    return run
