"""Cardinality estimation for partial pattern graphs (paper §4.3.1).

BENU reuses the model of Lai et al. [8] §5.1: under an Erdős–Rényi view of
the data graph (N vertices, M undirected edges, edge probability
``p_e = 2M / (N (N-1))``), the expected number of *matches* (injective
order-sensitive embeddings) of a pattern ``p`` with ``k`` used vertices and
``b`` edges is::

    E[#matches(p)] = N (N-1) ... (N-k+1) * p_e^b

Disconnected partial patterns multiply over connected components (the paper
handles this case explicitly). Isolated pattern vertices contribute a factor
of (remaining) N each — the product form ``P(N, k) * p_e^b`` already captures
that.

For S-BENU the paper treats incremental partial patterns as undirected and
reuses this model (§5.4); delta edges are rare, so we scale each delta edge by
``p_delta = |delta| / M`` when stats provide a batch size — this keeps order
search preferring plans that touch delta sets early, mirroring the fixed
(u_si, u_ti) prefix.

The model is deliberately pluggable (the paper: "The estimation model can be
replaced if a more accurate model is proposed later").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Sequence, Tuple


@dataclass(frozen=True)
class GraphStats:
    """Summary statistics of the data graph used for plan costing."""

    n_vertices: int
    n_edges: int                      # undirected edge count
    delta_edges: int = 0              # |Delta o_t| for S-BENU costing

    @property
    def p_edge(self) -> float:
        n = max(self.n_vertices, 2)
        return min(1.0, 2.0 * self.n_edges / (n * (n - 1)))

    @property
    def p_delta(self) -> float:
        if self.n_edges == 0:
            return 0.0
        return min(1.0, self.delta_edges / self.n_edges)


DEFAULT_STATS = GraphStats(n_vertices=1_000_000, n_edges=10_000_000)


def _components(vertices: Sequence[int],
                edges: Iterable[Tuple[int, int]]):
    vs = list(vertices)
    idx = {v: i for i, v in enumerate(vs)}
    parent = list(range(len(vs)))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    es = list(edges)
    for a, b in es:
        ra, rb = find(idx[a]), find(idx[b])
        if ra != rb:
            parent[ra] = rb
    comp = {}
    for v in vs:
        comp.setdefault(find(idx[v]), []).append(v)
    comps = []
    for members in comp.values():
        ms = set(members)
        comps.append((members, [e for e in es if e[0] in ms]))
    return comps


def estimate_matches(vertices: Sequence[int],
                     edges: Sequence[Tuple[int, int]],
                     stats: GraphStats = DEFAULT_STATS,
                     delta_flags: Optional[Sequence[bool]] = None) -> float:
    """Expected #matches of the partial pattern on ``vertices``/``edges``.

    ``delta_flags[i]`` marks ``edges[i]`` as a delta edge (S-BENU costing).
    """
    if not vertices:
        return 1.0
    n = stats.n_vertices
    pe = stats.p_edge
    pd = stats.p_delta if stats.delta_edges else pe
    flag = {tuple(e): bool(delta_flags[i]) for i, e in enumerate(edges)} \
        if delta_flags is not None else {}
    total = 1.0
    for members, comp_edges in _components(vertices, edges):
        cnt = 1.0
        for i in range(len(members)):
            cnt *= max(n - i, 1)
        for e in comp_edges:
            cnt *= pd if flag.get(tuple(e), False) else pe
        total *= max(cnt, 1e-30)
    return total


class PartialPatternTracker:
    """Incrementally tracks the partial pattern during order search /
    ESTIMATECOMPUTATIONCOST scans (paper Alg. 3)."""

    def __init__(self, pattern, stats: GraphStats = DEFAULT_STATS,
                 delta_edge: int = 0):
        self.pattern = pattern
        self.stats = stats
        self.vertices: list = []
        self.edges: list = []
        self.delta_flags: list = []
        # S-BENU: 1-based index of the delta edge in pattern.edges, 0=BENU
        self.delta_edge = delta_edge

    def clone(self) -> "PartialPatternTracker":
        t = PartialPatternTracker(self.pattern, self.stats, self.delta_edge)
        t.vertices = list(self.vertices)
        t.edges = list(self.edges)
        t.delta_flags = list(self.delta_flags)
        return t

    def add_vertex(self, u: int) -> None:
        present = set(self.vertices)
        self.vertices.append(u)
        for k, (a, b) in enumerate(self.pattern.edges, start=1):
            if (a == u and b in present) or (b == u and a in present):
                self.edges.append((min(a, b), max(a, b)))
                self.delta_flags.append(k == self.delta_edge)

    def estimate(self) -> float:
        return estimate_matches(self.vertices, self.edges, self.stats,
                                self.delta_flags)
