"""Unified Executor API: one driver, many enumeration backends.

B-BENU's central claim is that a single backtracking execution plan can
drive very different runtimes — per-task local search (the paper's worker
model), lockstep SPMD frontier expansion (one device or a whole mesh), and
streaming delta enumeration — without ever shuffling partial results. This
module is that claim expressed as code: every engine in the repo implements
the small :class:`ExecutorBackend` protocol (its fetch / intersect / shard
specifics only) and the **same** driver owns

* plan preprocessing (universe detection, capacity defaults),
* the frontier lifecycle (start-vertex batching, universe chunking),
* overflow accounting, and
* **adaptive task splitting** (paper §5.2, vectorized): when a chunk
  reports ENU overflow the driver first *re-chunks* the offending
  start-vertex batch into smaller halves and re-descends with smaller
  frontiers (same capacities, fewer roots -> fewer children per level);
  only when a chunk can no longer be split does it escalate to capacity
  doubling. No match is ever dropped: an overflowed chunk's partial result
  is discarded and the chunk is re-executed in a shape that fits.

Backends::

    ref        pure-Python oracle interpreter        (core/ref_engine.py)
    jax        single-device vectorized frontier     (core/engine_jax.py)
    jax-gpu    same engine, fused gather+intersect
               fetch path (kernels/gather_intersect
               .py; see docs/KERNELS.md)             (core/engine_jax.py)
    dist       shard_map SPMD over a device mesh     (core/engine_dist.py)
    oocache    out-of-core: host-RAM row shards +
               bounded device cache + async prefetch (core/engine_ooc.py)
    sbenu      continuous/delta enumeration          (core/sbenu.py)
    sbenu-jax  vectorized continuous enumeration     (core/engine_sbenu_jax.py)
    sbenu-dist shard_map SPMD continuous enumeration
               over the mesh-sharded six-block
               snapshot                              (core/engine_sbenu_dist.py)

Use :func:`make_executor` (or instantiate a backend directly) and call
:meth:`Executor.run`; all engines route through here, so every launcher,
benchmark, and conformance test shares one chunk-size / overflow policy.

Example (the reference interpreter; every other engine is a drop-in
``make_executor`` name swap)::

    >>> from repro.core.executor import make_executor
    >>> from repro.core.pattern import get_pattern
    >>> from repro.core.plangen import generate_best_plan
    >>> from repro.graph.generate import erdos_renyi
    >>> g = erdos_renyi(30, 60, seed=1)                # 30 vertices
    >>> plan = generate_best_plan(get_pattern("triangle"), g.stats())
    >>> stats = make_executor("ref").run(plan, g, batch=8)
    >>> stats.count == make_executor("ref").run(plan, g, batch=32).count
    True
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from ..graph.storage import Graph
from .instructions import ENU, Plan
from .pattern import Pattern


# --------------------------------------------------------------------------
# Shared frontier-lifecycle helpers (previously copied in every engine)
# --------------------------------------------------------------------------


def ceil_div(a: int, b: int) -> int:
    """``ceil(a / b)`` for non-negative ints (no float detour)."""
    return -(-a // b)


def start_id_batches(n: int, batch: int,
                     sentinel: Optional[int] = None
                     ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield ``(ids int32[batch], valid bool[batch])`` covering ``range(n)``."""
    sent = n if sentinel is None else sentinel
    for s0 in range(0, n, batch):
        ids = np.arange(s0, s0 + batch, dtype=np.int32)
        valid = ids < n
        yield np.where(valid, ids, sent).astype(np.int32), valid


def build_universe_chunks(n: int, width: int,
                          sentinel: Optional[int] = None) -> List[np.ndarray]:
    """Sentinel-padded slices of V(G) for plans with a detached vertex
    (the paper's |V(G)|/θ subtask split for non-adjacent (u_k1, u_k2))."""
    sent = n if sentinel is None else sentinel
    w = min(width, max(n, 1))
    chunks: List[np.ndarray] = []
    for u0 in range(0, n, w):
        c = np.full(w, sent, np.int32)
        hi = min(u0 + w, n)
        c[:hi - u0] = np.arange(u0, hi, dtype=np.int32)
        chunks.append(c)
    return chunks


def split_id_batch(ids: np.ndarray, valid: np.ndarray, granularity: int,
                   sentinel: int
                   ) -> Optional[List[Tuple[np.ndarray, np.ndarray]]]:
    """Split a start batch into two half-shaped batches (§5.2 task split).

    The valid ids are dealt evenly into two arrays of length
    ``ceil(B/2)`` rounded up to ``granularity`` (mesh width for the
    distributed backend). Returns ``None`` when the batch cannot shrink
    further.
    """
    B = ids.shape[0]
    # ceil(B/2) rounded up to granularity: a half always fits its
    # ceil(nv/2) valid ids — no start may ever be truncated away
    half = ceil_div(ceil_div(B, 2), granularity) * granularity if B > 1 else 0
    if half < granularity or half >= B:
        return None
    vids = ids[valid]
    out = []
    for part in (vids[0::2], vids[1::2]):
        a = np.full(half, sentinel, np.int32)
        v = np.zeros(half, bool)
        k = part.shape[0]
        a[:k] = part
        v[:k] = True
        out.append((a, v))
    return out


def plan_enu_count(plan: Plan) -> int:
    """Number of ENU instructions == number of per-level capacities a
    static-engine caps tuple must carry."""
    return sum(1 for ins in plan.instrs if ins.op == ENU)


# --------------------------------------------------------------------------
# Protocol types
# --------------------------------------------------------------------------


@dataclass
class ExecutorConfig:
    """Driver-level policy shared by every backend.

    Units: ``batch`` and ``universe_chunk`` count start vertices /
    universe ids per chunk; ``caps[i]`` counts child-frontier rows at the
    i-th ENU level; ``theta`` counts C2 candidates (the interpreter's
    task-split threshold, paper §6.3).
    """

    batch: int = 256                 # global start-vertex chunk size
    caps: Optional[Sequence[int]] = None   # per-ENU frontier capacities
    universe_chunk: int = 1024       # width of V(G) slices (detached vertex)
    max_retries: int = 6             # capacity-doubling budget per chunk
    adaptive_split: bool = True      # re-chunk before growing capacities
    collect_matches: bool = False
    intersect_impl: str = "auto"
    theta: Optional[int] = None      # interpreter task-split threshold


@dataclass
class ChunkResult:
    """One chunk execution. ``overflow``/``drops`` > 0 invalidates the
    result: the driver discards it and re-chunks or escalates."""

    count: int                       # matches found in the chunk
    overflow: int = 0                # children dropped at some ENU level
    drops: int = 0                   # fetch requests beyond req_cap (dist)
    matches: Optional[np.ndarray] = None   # int32[k, plan.n], valid rows only
    extras: Dict[str, Any] = field(default_factory=dict)


@dataclass
class ExecStats:
    """Driver result: exact totals + overflow/splitting accounting."""

    count: int = 0
    chunks_run: int = 0
    chunks_split: int = 0            # adaptive re-chunk events
    chunks_retried: int = 0          # capacity/request escalations
    drops_seen: int = 0
    matches: Optional[np.ndarray] = None
    extras: Dict[str, Any] = field(default_factory=dict)

    def merge_extras(self, other: Dict[str, Any]) -> None:
        """Accumulate a chunk's extras (values must support ``+``)."""
        for k, v in other.items():
            if k in self.extras:
                self.extras[k] = self.extras[k] + v
            else:
                self.extras[k] = v


class ExecutorBackend(ABC):
    """What an engine must provide: its fetch/intersect/shard specifics.

    The driver owns chunking, retries, and splitting; backends execute one
    fixed-shape chunk at a time and report overflow honestly.
    """

    name: str = "?"
    #: start-batch shapes must be multiples of this (mesh width for SPMD)
    granularity: int = 1
    #: frontier capacities must be multiples of this: the driver rounds
    #: every caps tuple it hands out (initial and escalated) up to it.
    #: SPMD backends set the mesh size — their rebalancer stripes a local
    #: frontier round-robin over the axis, which needs cap % S == 0
    cap_multiple: int = 1
    #: whether the driver may re-chunk this backend's batches
    splittable: bool = True

    @abstractmethod
    def prepare(self, plan: Any, source: Any, config: ExecutorConfig) -> None:
        """Plan preprocessing + device placement. Called once per run."""

    @abstractmethod
    def run_chunk(self, ids: np.ndarray, valid: np.ndarray,
                  universe_chunk: Optional[np.ndarray],
                  caps: Tuple[int, ...]) -> ChunkResult:
        """Execute one fixed-shape chunk of start vertices."""

    def start_batches(self, config: ExecutorConfig
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield ``(ids int32[batch], valid bool[batch])`` start chunks."""
        yield from start_id_batches(self._n_starts(), config.batch)

    def universe_chunks(self, config: ExecutorConfig
                        ) -> Sequence[Optional[np.ndarray]]:
        """Sentinel-padded V(G) slices (``int32[W]``) for detached-vertex
        plans; ``[None]`` when the plan never consumes V(G)."""
        return [None]

    def initial_caps(self, config: ExecutorConfig) -> Tuple[int, ...]:
        """Per-ENU child-frontier capacities (rows) for the first attempt."""
        return ()

    def grow_caps(self, caps: Tuple[int, ...]) -> Tuple[int, ...]:
        """Escalated capacities once a chunk is unsplittable (default 2x)."""
        return tuple(int(c * 2) for c in caps)

    def escalate_requests(self) -> None:
        """Called when a chunk reported request drops (dist fetch only)."""

    def finalize(self, stats: ExecStats) -> None:
        """Attach backend-specific extras to the driver stats."""

    def _n_starts(self) -> int:
        raise NotImplementedError


# --------------------------------------------------------------------------
# The adaptive task-splitting driver
# --------------------------------------------------------------------------


def drive(backend: ExecutorBackend, plan: Any, source: Any,
          config: ExecutorConfig) -> ExecStats:
    """Run ``plan`` over ``source`` on ``backend`` — exactly.

    A chunk that overflows is never silently truncated: its (partial)
    result is discarded, and the driver re-descends either on two smaller
    sub-chunks (adaptive task splitting — same capacities, smaller
    frontiers) or, once a chunk is a single unsplittable batch, with
    doubled capacities.
    """
    backend.prepare(plan, source, config)
    stats = ExecStats()
    all_matches: List[np.ndarray] = []
    # every caps tuple the driver hands out is rounded up to the backend's
    # cap_multiple (read after prepare(): SPMD backends learn their mesh
    # size there). This is what keeps user-supplied or degree-derived odd
    # capacities from tripping the rebalancer's cap % mesh-size assert.
    mult = max(int(getattr(backend, "cap_multiple", 1)), 1)

    def round_caps(caps: Sequence[int]) -> Tuple[int, ...]:
        return tuple(ceil_div(int(c), mult) * mult for c in caps)

    caps0 = round_caps(backend.initial_caps(config))
    sentinel = getattr(backend, "sentinel", 0)
    for ids, valid in backend.start_batches(config):
        for uni in backend.universe_chunks(config):
            # (ids, valid, caps, escalations) — LIFO work stack
            work: List[Tuple[np.ndarray, np.ndarray, Tuple[int, ...], int]]
            work = [(ids, valid, caps0, 0)]
            while work:
                cids, cvalid, caps, tries = work.pop()
                if not cvalid.any():
                    continue
                res = backend.run_chunk(cids, cvalid, uni, caps)
                stats.chunks_run += 1
                ok = res.overflow == 0 and res.drops == 0
                if ok:
                    stats.count += int(res.count)
                    stats.merge_extras(res.extras)
                    if res.matches is not None:
                        all_matches.append(res.matches)
                    continue
                if res.drops > 0:
                    stats.drops_seen += int(res.drops)
                    backend.escalate_requests()
                halves = None
                if (res.overflow > 0 and config.adaptive_split
                        and backend.splittable):
                    halves = split_id_batch(cids, cvalid,
                                            backend.granularity, sentinel)
                if halves is not None:
                    stats.chunks_split += 1
                    for h_ids, h_valid in halves:
                        work.append((h_ids, h_valid, caps, tries))
                    continue
                if tries >= config.max_retries:
                    raise RuntimeError(
                        f"[{backend.name}] chunk overflowed after "
                        f"{tries} escalations (caps={caps})")
                stats.chunks_retried += 1
                new_caps = round_caps(backend.grow_caps(caps)) \
                    if res.overflow else caps
                work.append((cids, cvalid, new_caps, tries + 1))
    if config.collect_matches:
        stats.matches = (np.concatenate(all_matches, axis=0) if all_matches
                         else np.zeros((0, getattr(plan, "n", 0)), np.int32))
    backend.finalize(stats)
    return stats


class Executor:
    """Facade: ``Executor(backend).run(plan, graph, batch=..., ...)``."""

    def __init__(self, backend: ExecutorBackend):
        self.backend = backend

    def run(self, plan: Any, source: Any,
            config: Optional[ExecutorConfig] = None, **kwargs) -> ExecStats:
        """Enumerate ``plan`` over ``source`` exactly; ``kwargs`` are
        :class:`ExecutorConfig` fields (``batch=``, ``caps=``, ...)."""
        cfg = config if config is not None else ExecutorConfig(**kwargs)
        return drive(self.backend, plan, source, cfg)


# --------------------------------------------------------------------------
# Backend: reference interpreter (pure Python oracle)
# --------------------------------------------------------------------------


class RefBackend(ExecutorBackend):
    """Per-task backtracking interpreter; the correctness oracle.

    Capacities do not exist here (recursion never overflows), but the
    paper's θ task splitting does: heavy start vertices split into C2
    slices inside :meth:`run_chunk`.
    """

    name = "ref"
    splittable = True

    def __init__(self, db=None, collect: str = "count",
                 pattern: Optional[Pattern] = None):
        self._db = db
        self._collect = collect
        self._given_pattern = pattern
        self.engine = None

    def prepare(self, plan: Plan, source: Graph,
                config: ExecutorConfig) -> None:
        from .ref_engine import RefEngine
        self.plan, self.graph = plan, source
        self.sentinel = source.n
        collect = self._collect
        if config.collect_matches and collect == "count":
            collect = "matches"
        self.engine = RefEngine(plan, self._pattern(plan), source,
                                db=self._db, collect=collect)
        self._theta = config.theta

    def _pattern(self, plan: Plan) -> Pattern:
        if self._given_pattern is not None:
            return self._given_pattern
        from .pattern import get_pattern
        return get_pattern(plan.pattern_name)

    def _n_starts(self) -> int:
        return self.graph.n

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        from .ref_engine import tasks_for_starts
        eng = self.engine
        tasks = tasks_for_starts(self.plan, eng.pattern, self.graph,
                                 ids[valid], theta=self._theta)
        m0 = eng.counters.matches
        k0 = len(eng.matches)
        eng.run(tasks=tasks)
        matches = None
        if eng.collect == "matches":
            matches = np.asarray(eng.matches[k0:], np.int32).reshape(
                -1, self.plan.n)
        return ChunkResult(count=eng.counters.matches - m0, matches=matches)

    def finalize(self, stats: ExecStats) -> None:
        c = self.engine.counters
        stats.extras.update(
            dbq=c.dbq, int_=c.int_, trc=c.trc, trc_hits=c.trc_hits,
            enu=c.enu, per_task_work=list(c.per_task_work),
            remote_queries=self.engine.db.remote_queries,
            total_queries=self.engine.db.total_queries)


# --------------------------------------------------------------------------
# Backend: single-device vectorized frontier engine
# --------------------------------------------------------------------------


class JaxBackend(ExecutorBackend):
    """Lockstep frontier expansion on one device (core/engine_jax.py).

    ``fused`` turns on the fused gather+intersect fetch path
    (kernels/gather_intersect.py): single-use DBQ row sets are never
    materialized — the consuming INT probes the adjacency rows straight
    out of the Pallas pipeline. Left ``None``, the ``REPRO_FUSED_FETCH``
    environment toggle decides (off by default; the ``jax-gpu`` backend
    defaults it on). ``gather_intersect_impl`` picks the fused kernel
    impl (auto | pallas | interpret | ref/chunked/binary fallbacks).
    """

    name = "jax"

    #: what REPRO_FUSED_FETCH falls back to when unset and fused=None
    #: (JaxGpuBackend flips it to True)
    _fused_default = False

    def __init__(self, compaction: str = "cumsum",
                 fused: Optional[bool] = None,
                 gather_intersect_impl: str = "auto"):
        self._compaction = compaction
        self._fused_arg = fused
        self._gi_impl = gather_intersect_impl

    def prepare(self, plan: Plan, source: Graph,
                config: ExecutorConfig) -> None:
        import jax
        from ..kernels import dispatch
        from .engine_jax import (DeviceGraph, check_jit_supported,
                                 default_caps)
        self.plan, self.graph = plan, source
        self.dg = DeviceGraph.from_graph(source)
        self.fetch = self.dg.local_fetch()
        self.sentinel = self.dg.n
        self.has_universe = check_jit_supported(plan)
        self._caps0 = tuple(config.caps) if config.caps is not None else \
            tuple(default_caps(plan, config.batch, self.dg.d))
        self._collect = config.collect_matches
        self._intersect = config.intersect_impl
        self.fused = (self._fused_arg if self._fused_arg is not None
                      else dispatch.fused_fetch_enabled(self._fused_default))
        self._jit = jax.jit
        self._runners: Dict[Tuple[int, Tuple[int, ...]], Callable] = {}
        self._level_acc: Optional[np.ndarray] = None

    def _n_starts(self) -> int:
        return self.graph.n

    def universe_chunks(self, config: ExecutorConfig):
        if not self.has_universe:
            return [None]
        return build_universe_chunks(self.graph.n, config.universe_chunk)

    def initial_caps(self, config: ExecutorConfig) -> Tuple[int, ...]:
        return self._caps0

    def _runner(self, B: int, caps: Tuple[int, ...]) -> Callable:
        key = (B, caps)
        if key not in self._runners:
            from .engine_jax import build_enumerator
            run = build_enumerator(self.plan, self.sentinel, caps, self.fetch,
                                   collect_matches=self._collect,
                                   intersect_impl=self._intersect,
                                   compaction=self._compaction,
                                   fused_rows=(self.dg.rows if self.fused
                                               else None),
                                   gather_intersect_impl=self._gi_impl)
            self._runners[key] = self._jit(run)
        return self._runners[key]

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        import jax.numpy as jnp
        args = (jnp.asarray(ids), jnp.asarray(valid))
        if universe_chunk is not None:
            args = args + (jnp.asarray(universe_chunk),)
        res = self._runner(ids.shape[0], caps)(*args)
        ov = int(res.overflow)
        matches = None
        if self._collect and ov == 0 and res.matches is not None:
            m = np.asarray(res.matches)
            matches = m[np.asarray(res.matches_valid)]
        if ov == 0 and res.level_sizes:
            # accepted chunks only: aggregate frontier occupancy per ENU
            # level (benchmarks/roofline.py --fused reads this to model
            # achieved vs lane-math bytes for the fetch paths)
            lv = np.asarray([int(s) for s in res.level_sizes], np.int64)
            self._level_acc = (lv if self._level_acc is None
                               else self._level_acc + lv)
        return ChunkResult(count=int(res.count), overflow=ov,
                           matches=matches)

    def finalize(self, stats: ExecStats) -> None:
        stats.extras.update(
            level_sizes=(self._level_acc if self._level_acc is not None
                         else np.zeros(0, np.int64)),
            fused_fetch=self.fused)


class JaxGpuBackend(JaxBackend):
    """The accelerator fetch path: ``jax`` with fused gather+intersect on.

    BENU's hot loop — gather adjacency rows, intersect with the candidate
    set — is memory-bound; this backend keeps it in VMEM/registers
    (kernels/gather_intersect.py) instead of round-tripping a ``[B, D]``
    gather block through HBM. On a real GPU/TPU the dispatch registry
    resolves the fused kernel to the compiled Pallas path; on the CPU CI
    container it falls back to the unfused reference unless interpret
    mode is forced (``gather_intersect_impl="interpret"`` or
    ``REPRO_GATHER_INTERSECT_IMPL=pallas-interpret``), which is how the
    conformance matrix covers it. Counts and match sets are bit-equal to
    ``jax`` either way. Fusion defaults on; ``REPRO_FUSED_FETCH=0``
    turns it off (A/B debugging) without leaving this backend.
    """

    name = "jax-gpu"
    _fused_default = True

    def __init__(self, compaction: str = "cumsum",
                 gather_intersect_impl: str = "auto"):
        super().__init__(compaction=compaction,
                         gather_intersect_impl=gather_intersect_impl)


# --------------------------------------------------------------------------
# Backend: shard_map SPMD over a device mesh
# --------------------------------------------------------------------------


class DistBackend(ExecutorBackend):
    """Mesh-wide SPMD frontier engine with the distributed row store."""

    name = "dist"

    def __init__(self, mesh=None, axis: str = "shard", hot: int = 0,
                 rebalance: bool = False, req_cap: Optional[int] = None):
        self._mesh = mesh
        self._axis = axis
        self._hot = hot
        self._rebalance = rebalance
        self._req_cap0 = req_cap

    def prepare(self, plan: Plan, source: Graph,
                config: ExecutorConfig) -> None:
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..distributed.rowstore import build_row_shards
        from .engine_jax import check_jit_supported, default_caps
        from .engine_dist import enumeration_mesh
        self.plan, self.graph = plan, source
        mesh = self._mesh if self._mesh is not None else enumeration_mesh(
            self._axis)
        self.mesh = mesh
        self.S = mesh.devices.size
        self.granularity = self.S
        self.cap_multiple = self.S       # rebalancer stripes (driver rounds)
        shards_np, hot_np, spec = build_row_shards(source, self.S,
                                                   hot=self._hot)
        self.spec = spec
        self.sentinel = spec.n
        self.has_universe = check_jit_supported(plan)
        batch_per_shard = max(config.batch // self.S, 1)
        caps = list(config.caps) if config.caps is not None else \
            default_caps(plan, batch_per_shard, spec.d)
        # caps divisible by S for the rebalancer stripes
        self._caps0 = tuple(-(-c // self.S) * self.S for c in caps)
        self.req_cap = self._req_cap0 if self._req_cap0 is not None else \
            max(64, 2 * batch_per_shard // self.S)
        self._intersect = config.intersect_impl
        with jax.default_device(jax.devices()[0]):
            self.shards = jax.device_put(
                shards_np, NamedSharding(mesh, P(self._axis, None, None)))
            self.hot_rows = jax.device_put(
                hot_np, NamedSharding(mesh, P(None, None)))
        self._uni = [
            jax.device_put(jnp.asarray(c), NamedSharding(mesh, P(None)))
            for c in build_universe_chunks(source.n, config.universe_chunk)
        ] if self.has_universe else [None]
        self._id_sharding = NamedSharding(mesh, P(self._axis))
        self._steps: Dict[Tuple[Tuple[int, ...], int], Callable] = {}
        self._per_shard = np.zeros(self.S, np.int64)
        self._level_acc: Optional[np.ndarray] = None
        self._cold = 0

    def _n_starts(self) -> int:
        return self.graph.n

    def start_batches(self, config: ExecutorConfig):
        gbatch = -(-config.batch // self.S) * self.S
        yield from start_id_batches(self.graph.n, gbatch)

    def universe_chunks(self, config: ExecutorConfig):
        return self._uni

    def initial_caps(self, config: ExecutorConfig) -> Tuple[int, ...]:
        return self._caps0

    def escalate_requests(self) -> None:
        self.req_cap *= 2

    def _step(self, caps: Tuple[int, ...], req_cap: int) -> Callable:
        key = (caps, req_cap)
        if key not in self._steps:
            from .engine_dist import build_distributed_step
            self._steps[key] = build_distributed_step(
                self.plan, self.spec, self.mesh, self._axis, caps, req_cap,
                rebalance=self._rebalance, intersect_impl=self._intersect)
        return self._steps[key]

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        import jax
        import jax.numpy as jnp
        args = [self.shards, self.hot_rows,
                jax.device_put(jnp.asarray(ids), self._id_sharding),
                jax.device_put(jnp.asarray(valid), self._id_sharding)]
        if universe_chunk is not None:
            args.append(universe_chunk)
        counts, overflow, cold, drops, levels = self._step(
            caps, self.req_cap)(*args)
        ov = int(np.sum(np.asarray(overflow)))
        dr = int(np.sum(np.asarray(drops)))
        if ov == 0 and dr == 0:
            counts64 = np.asarray(counts, dtype=np.int64)
            self._per_shard += counts64
            self._cold += int(np.sum(np.asarray(cold)))
            lv = np.asarray(levels)
            self._level_acc = (lv if self._level_acc is None
                               else self._level_acc + lv)
            return ChunkResult(count=int(counts64.sum()))
        return ChunkResult(count=0, overflow=ov, drops=dr)

    def finalize(self, stats: ExecStats) -> None:
        stats.extras.update(
            per_shard_counts=self._per_shard,
            per_shard_level_sizes=(
                self._level_acc if self._level_acc is not None
                else np.zeros((0, self.S))),
            cold_rows_fetched=self._cold)


# --------------------------------------------------------------------------
# Backend: out-of-core fetch path (host-RAM shards + device row cache)
# --------------------------------------------------------------------------


class OocBackend(ExecutorBackend):
    """Out-of-core vectorized enumeration (core/engine_ooc.py, paper §6).

    The padded adjacency lives in host-RAM shards
    (:class:`~repro.graph.hoststore.HostRowStore`); device memory holds a
    bounded row cache (:class:`~repro.distributed.rowcache.DeviceRowCache`:
    ``cache_rows`` LRU slots + the top-``hot``-by-degree rows pinned).
    Every DBQ level dedups its id batch and pulls only the cold rows from
    the host — communication scales with distinct cold rows, never partial
    matches — and the next chunk's start rows are prefetched
    (double-buffered async ``device_put``) while the current chunk
    computes.

    Sizing: ``cache_rows``/``hot``/``stage_rows`` count rows (``D * 4``
    bytes each); when omitted, ``cache_rows``/``hot`` default to
    ``cache_frac`` / ``hot_frac`` of the graph's N rows and
    ``stage_rows`` to ``cache_rows // 4`` per staging buffer. Worst-case
    device residency is ``cache_rows + 2 * stage_rows + hot + 1`` rows
    total (slab + both prefetch buffers + pinned hot + sentinel),
    independent of graph size.
    """

    name = "oocache"
    splittable = True

    def __init__(self, cache_rows: Optional[int] = None,
                 cache_frac: float = 0.15,
                 hot: Optional[int] = None, hot_frac: float = 0.05,
                 prefetch: bool = True, stage_rows: Optional[int] = None,
                 rows_per_shard: int = 4096,
                 compaction: str = "cumsum"):
        self._cache_rows = cache_rows
        self._cache_frac = cache_frac
        self._hot = hot
        self._hot_frac = hot_frac
        self._prefetch = prefetch
        self._stage_rows = stage_rows
        self._rows_per_shard = rows_per_shard
        self._compaction = compaction
        self.cache = None
        self.store = None

    def prepare(self, plan: Plan, source: Graph,
                config: ExecutorConfig) -> None:
        from ..distributed.rowcache import DeviceRowCache
        from ..graph.hoststore import HostRowStore
        from .engine_jax import check_jit_supported, default_caps
        from .engine_ooc import OocEngine
        self.plan, self.graph = plan, source
        n = source.n
        self.sentinel = n
        self.store = HostRowStore.from_graph(
            source, rows_per_shard=self._rows_per_shard)
        cap = self._cache_rows if self._cache_rows is not None else \
            max(1, int(n * self._cache_frac))
        hot = self._hot if self._hot is not None else \
            max(0, int(n * self._hot_frac))
        self.cache = DeviceRowCache(self.store, cap, hot=hot,
                                    stage_rows=self._stage_rows)
        self.has_universe = check_jit_supported(plan)
        self._caps0 = tuple(config.caps) if config.caps is not None else \
            tuple(default_caps(plan, config.batch, self.store.d))
        self.engine = OocEngine(plan, self.cache,
                                collect_matches=config.collect_matches,
                                intersect_impl=config.intersect_impl,
                                compaction=self._compaction)

    def _n_starts(self) -> int:
        return self.graph.n

    def start_batches(self, config: ExecutorConfig):
        """Yield start batches, prefetching batch ``k + 1``'s rows right
        before handing batch ``k`` to the driver: the async H2D copy
        overlaps batch ``k``'s segment compute (double buffering)."""
        batches = list(start_id_batches(self.graph.n, config.batch))
        for k, (ids, valid) in enumerate(batches):
            if self._prefetch and k + 1 < len(batches):
                nxt_ids, nxt_valid = batches[k + 1]
                self.cache.prefetch(nxt_ids[nxt_valid])
            yield ids, valid

    def universe_chunks(self, config: ExecutorConfig):
        if not self.has_universe:
            return [None]
        return build_universe_chunks(self.graph.n, config.universe_chunk)

    def initial_caps(self, config: ExecutorConfig) -> Tuple[int, ...]:
        return self._caps0

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        count, overflow, matches, _ = self.engine.run_chunk(
            ids, valid, universe_chunk, caps)
        return ChunkResult(count=count, overflow=overflow, matches=matches)

    def finalize(self, stats: ExecStats) -> None:
        stats.extras.update(
            cache=self.cache.stats.as_dict(),
            cache_capacity_rows=self.cache.capacity_rows,
            cache_hot_rows=self.cache.hot,
            device_resident_rows=self.cache.device_rows,
            device_resident_bytes=self.cache.device_bytes,
            host_store_bytes=self.store.nbytes,
            host_store_shards=len(self.store.shards))


# --------------------------------------------------------------------------
# Backend: S-BENU continuous enumeration (delta tasks on a SnapshotStore)
# --------------------------------------------------------------------------


class SBenuBackend(ExecutorBackend):
    """Delta enumeration over a SnapshotStore (core/sbenu.py).

    Start vertices are the batch's update endpoints; heavy tasks θ-split on
    their delta adjacency list. Source = a begun SnapshotStore; plan = the
    list of incremental plans for every ΔP_i.
    """

    name = "sbenu"
    splittable = True

    def __init__(self, pattern: Pattern, cache_capacity: Optional[int] = None,
                 collect: str = "matches"):
        self._pattern = pattern
        self._cache_capacity = cache_capacity
        self._collect = collect
        self.engine = None

    def prepare(self, plans: Sequence[Plan], source,
                config: ExecutorConfig) -> None:
        from .sbenu import SBenuRefEngine
        self.store = source
        self.sentinel = -1
        self._starts = np.asarray(sorted(source.start_vertices()), np.int32)
        self.engine = SBenuRefEngine(plans, self._pattern, source,
                                     collect=self._collect,
                                     cache_capacity=self._cache_capacity)
        self._theta = config.theta

    def start_batches(self, config: ExecutorConfig):
        n = self._starts.shape[0]
        for s0 in range(0, max(n, 1), config.batch):
            ids = self._starts[s0:s0 + config.batch]
            if ids.shape[0] == 0:
                return
            yield ids, np.ones(ids.shape[0], bool)

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        eng = self.engine
        c0 = eng.counters.matches_plus + eng.counters.matches_minus
        eng.run_starts(ids[valid], theta=self._theta)
        c1 = eng.counters.matches_plus + eng.counters.matches_minus
        return ChunkResult(count=c1 - c0)

    def finalize(self, stats: ExecStats) -> None:
        stats.extras.update(
            delta_plus=set(self.engine.delta_plus),
            delta_minus=set(self.engine.delta_minus),
            counters=self.engine.counters)


# --------------------------------------------------------------------------
# Backend: vectorized S-BENU (JIT delta-frontier engine over the six-block
# device snapshot)
# --------------------------------------------------------------------------


class SBenuJaxBackend(ExecutorBackend):
    """Lockstep delta-frontier enumeration (core/engine_sbenu_jax.py).

    ``plan`` is the list of incremental plans (one per ΔP_i); ``source`` is
    a *begun* SnapshotStore. Start batches cover the touched-vertex set of
    the update batch (vertices with non-empty ΔΓ_out), never all of V(G);
    every plan runs over each chunk, and a chunk whose total overflow is
    non-zero is discarded whole and re-split by the shared driver.
    """

    name = "sbenu-jax"
    splittable = True

    def __init__(self, pattern: Optional[Pattern] = None,
                 collect: str = "matches", lane: int = 8,
                 d_min: int = 0, delta_d_min: int = 0,
                 compaction: str = "cumsum",
                 snapshot_storage: str = "device"):
        self._pattern = pattern          # unused; parity with SBenuBackend
        self._collect_mode = collect
        self._lane = lane
        self._d_min = d_min
        self._delta_d_min = delta_d_min
        self._compaction = compaction
        # 'device' keeps prev blocks resident in HBM across steps;
        # 'host' keeps them in HostRowStore shards (host RAM), advanced
        # in place — zero persistent device residency between steps
        self._snapshot_storage = snapshot_storage
        # runner cache outlives prepare(): a backend reused across time
        # steps (run_timestep(backend=...)) compiles once per stream as
        # long as the snapshot widths stay pinned (d_min / delta_d_min)
        self._runners: Dict[Tuple[int, int, Tuple[int, ...]], Callable] = {}

    def prepare(self, plans: Sequence[Plan], source,
                config: ExecutorConfig) -> None:
        import jax
        from ..graph.dynamic import DeviceSnapshotStore
        from .engine_sbenu_jax import plan_level_count
        self.plans = list(plans)
        # the runner cache keys on plan identity: a *different* plan list
        # invalidates it (ids of collected plans could be recycled);
        # self.plans keeps the current ones alive for the cache lifetime
        plan_ids = tuple(id(p) for p in self.plans)
        if getattr(self, "_cached_plan_ids", None) != plan_ids:
            self._runners.clear()
            self._cached_plan_ids = plan_ids
        self.store = source
        self.sentinel = source.n
        self._starts = np.asarray(sorted(source.start_vertices()), np.int32)
        # device-resident dual-snapshot store: prev blocks stay on device
        # across steps; G'_t is derived lane-wise from prev + delta
        dstore = DeviceSnapshotStore.for_store(
            source, lane=self._lane, d_min=self._d_min,
            delta_d_min=self._delta_d_min,
            storage=self._snapshot_storage)
        self.snap = dstore.step_snapshot()
        # the Delta-ENU level has an exact bound: the worst chunk's total
        # delta-edge count (each start emits exactly its delta row) — far
        # tighter than batch * d_delta, keeping frontiers cache-resident
        degs = np.array([len(source.delta_adj_out(int(v)))
                         for v in self._starts], np.int64)
        B = config.batch
        denu_cap = int(max((degs[s0:s0 + B].sum()
                            for s0 in range(0, len(degs), B)), default=B))
        denu_cap = max(denu_cap, B, 8)
        # round up to a power of two: steps with similar churn share one
        # compiled shape instead of retracing every step
        denu_cap = 1 << (denu_cap - 1).bit_length()
        # average degree drives fan-out levels (single-adjacency ENUs)
        avg_deg = max(1, round(source.prev.m / max(source.n, 1)))
        # one caps tuple for the whole chunk: per-plan slices, concatenated
        # (plans have different level counts; the driver grows all slices)
        from .engine_sbenu_jax import sbenu_level_fanouts
        self._offsets: List[Tuple[int, int]] = []
        caps: List[int] = []
        for plan in self.plans:
            n_lv = plan_level_count(plan)
            if config.caps is not None:
                c = list(config.caps)[:n_lv]
                c += [c[-1]] * (n_lv - len(c))
            else:
                # contraction levels keep the exact Delta-ENU bound; a
                # fan-out level (candidates = one typed adjacency) scales
                # by ~avg degree. The driver re-splits the heavy tail.
                c, cur = [], denu_cap
                for fans in sbenu_level_fanouts(plan):
                    if fans:
                        cur = min(cur * 2 * avg_deg, 1 << 22)
                        cur = 1 << (cur - 1).bit_length()
                    c.append(cur)
            self._offsets.append((len(caps), len(caps) + len(c)))
            caps.extend(c)
        self._caps0 = tuple(caps)
        self._collect = config.collect_matches or \
            self._collect_mode == "matches"
        self._intersect = config.intersect_impl
        self._jit = jax.jit
        self._plus: List[Tuple[int, ...]] = []
        self._minus: List[Tuple[int, ...]] = []
        self._count_plus = 0
        self._count_minus = 0

    def _n_starts(self) -> int:
        return self._starts.shape[0]

    def start_batches(self, config: ExecutorConfig):
        n, B = self._starts.shape[0], config.batch
        for s0 in range(0, n, B):
            chunk = self._starts[s0:s0 + B]
            ids = np.full(B, self.sentinel, np.int32)
            ids[:chunk.shape[0]] = chunk
            valid = np.zeros(B, bool)
            valid[:chunk.shape[0]] = True
            yield ids, valid

    def initial_caps(self, config: ExecutorConfig) -> Tuple[int, ...]:
        return self._caps0

    def _runner(self, B: int, caps: Tuple[int, ...]) -> Callable:
        key = (tuple(id(p) for p in self.plans), B, caps)
        if key not in self._runners:
            from .engine_sbenu_jax import build_sbenu_multi_enumerator
            caps_list = [tuple(caps[lo:hi]) for lo, hi in self._offsets]
            run = build_sbenu_multi_enumerator(
                self.plans, self.sentinel, caps_list,
                collect_matches=self._collect,
                intersect_impl=self._intersect,
                compaction=self._compaction)
            self._runners[key] = self._jit(run)
        return self._runners[key]

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        import jax.numpy as jnp
        jids, jvalid = jnp.asarray(ids), jnp.asarray(valid)
        # all ΔP_i plans run in one fused dispatch per chunk
        res = self._runner(ids.shape[0], tuple(caps))(self.snap, jids,
                                                      jvalid)
        ov = int(res.overflow)
        if ov:
            # discard the whole chunk; the driver re-splits or grows
            return ChunkResult(count=0, overflow=ov)
        cp, cm = int(res.count_plus), int(res.count_minus)
        if self._collect and res.matches is not None:
            mv = np.asarray(res.matches_valid)
            rows = np.asarray(res.matches)[mv]
            ops = np.asarray(res.match_ops)[mv]
            for row, o in zip(rows, ops):
                (self._plus if o > 0 else self._minus).append(
                    tuple(int(x) for x in row))
        self._count_plus += cp
        self._count_minus += cm
        return ChunkResult(count=cp + cm)

    def finalize(self, stats: ExecStats) -> None:
        from .sbenu import SBenuCounters
        ctr = SBenuCounters(matches_plus=self._count_plus,
                            matches_minus=self._count_minus)
        stats.extras.update(delta_plus=set(self._plus),
                            delta_minus=set(self._minus),
                            counters=ctr)


# --------------------------------------------------------------------------
# Backend: distributed S-BENU (shard_map SPMD over the sharded six-block
# snapshot)
# --------------------------------------------------------------------------


class SBenuDistBackend(ExecutorBackend):
    """Mesh-wide SPMD delta-frontier engine (core/engine_sbenu_dist.py).

    The six-block snapshot is row-block partitioned over the enumeration
    mesh and stays resident across time steps
    (:class:`~repro.graph.dynamic.ShardedDeviceSnapshotStore`); typed DBQs
    are request/response all_to_alls against the owning shard with the
    top-``hot`` rows replicated; ΔR_t^± counts (and collected match rows)
    come back per shard and are reduced here. Start batches shard evenly
    (``granularity = S``) and frontier capacities are per *shard*, kept
    divisible by the mesh size through the driver's ``cap_multiple``
    contract (required by the opt-in rebalancer's stripe exchange).
    """

    name = "sbenu-dist"
    splittable = True

    def __init__(self, pattern: Optional[Pattern] = None,
                 collect: str = "matches", lane: int = 8,
                 d_min: int = 0, delta_d_min: int = 0,
                 compaction: str = "cumsum",
                 mesh=None, axis: str = "shard", hot: int = 0,
                 rebalance: bool = False, req_cap: Optional[int] = None):
        self._pattern = pattern          # unused; parity with SBenuBackend
        self._collect_mode = collect
        self._lane = lane
        self._d_min = d_min
        self._delta_d_min = delta_d_min
        self._compaction = compaction
        self._mesh = mesh
        self._axis = axis
        self._hot = hot
        self._rebalance = rebalance
        self._req_cap0 = req_cap
        # compiled shard_map steps outlive prepare(): one compile per
        # stream as long as snapshot widths stay pinned (d_min/delta_d_min)
        self._runners: Dict[Tuple, Callable] = {}

    def prepare(self, plans: Sequence[Plan], source,
                config: ExecutorConfig) -> None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..graph.dynamic import ShardedDeviceSnapshotStore
        from .engine_dist import enumeration_mesh
        from .engine_sbenu_jax import plan_level_count, sbenu_level_fanouts
        self.plans = list(plans)
        plan_ids = tuple(id(p) for p in self.plans)
        if getattr(self, "_cached_plan_ids", None) != plan_ids:
            self._runners.clear()
            self._cached_plan_ids = plan_ids
        mesh = self._mesh if self._mesh is not None else enumeration_mesh(
            self._axis)
        self.mesh = mesh
        self.S = int(mesh.devices.size)
        self.granularity = self.S
        self.cap_multiple = self.S
        self.store = source
        self.sentinel = source.n
        self._starts = np.asarray(sorted(source.start_vertices()), np.int32)
        dstore = ShardedDeviceSnapshotStore.for_store(
            source, mesh, axis=self._axis, lane=self._lane,
            d_min=self._d_min, delta_d_min=self._delta_d_min,
            hot=self._hot)
        self.dstore = dstore
        blocks, hot_blocks, self.spec = dstore.step_sharded()
        from .engine_sbenu_dist import BLOCK_ORDER
        self._block_args = tuple(blocks[k] for k in BLOCK_ORDER) + \
            tuple(hot_blocks[k] for k in BLOCK_ORDER)
        self._widths = tuple(int(blocks[k].shape[1]) for k in BLOCK_ORDER)
        # global batch: a multiple of S so shard_map splits starts evenly
        self._B = ceil_div(max(config.batch, self.S), self.S) * self.S
        w = self._B // self.S
        # per-shard Delta-ENU bound: each start emits exactly its delta
        # row, and a shard owns a contiguous w-slice of the chunk — the
        # worst slice's delta-edge total bounds the local first level
        degs = np.array([len(source.delta_adj_out(int(v)))
                         for v in self._starts], np.int64)
        denu_cap = w
        for s0 in range(0, len(degs), self._B):
            chunk = degs[s0:s0 + self._B]
            for k in range(self.S):
                denu_cap = max(denu_cap, int(chunk[k * w:(k + 1) * w].sum()))
        denu_cap = max(denu_cap, 8)
        denu_cap = 1 << (denu_cap - 1).bit_length()
        avg_deg = max(1, round(source.prev.m / max(source.n, 1)))
        # one caps tuple for the whole chunk: per-plan slices, concatenated
        # (same policy as the single-device backend; driver rounds each
        # entry up to cap_multiple = S)
        self._offsets: List[Tuple[int, int]] = []
        caps: List[int] = []
        for plan in self.plans:
            n_lv = plan_level_count(plan)
            if config.caps is not None:
                c = list(config.caps)[:n_lv]
                c += [c[-1]] * (n_lv - len(c))
            else:
                c, cur = [], denu_cap
                for fans in sbenu_level_fanouts(plan):
                    if fans:
                        cur = min(cur * 2 * avg_deg, 1 << 22)
                        cur = 1 << (cur - 1).bit_length()
                    c.append(cur)
            self._offsets.append((len(caps), len(caps) + len(c)))
            caps.extend(c)
        self._caps0 = tuple(caps)
        # per-peer request budget: ~2x the worst per-owner distinct-id load
        # of a frontier level, bounded so the [S, R, D] exchange buffers
        # stay modest — a heavy level that still drops escalates (2x) and
        # the chunk retries, which is exact
        self.req_cap = self._req_cap0 if self._req_cap0 is not None else \
            max(64, min(2 * max(self._caps0) // self.S, 8192))
        self._collect = config.collect_matches or \
            self._collect_mode == "matches"
        self._intersect = config.intersect_impl
        self._id_sharding = NamedSharding(mesh, P(self._axis))
        self._plus: List[Tuple[int, ...]] = []
        self._minus: List[Tuple[int, ...]] = []
        self._count_plus = 0
        self._count_minus = 0
        self._per_shard = np.zeros(self.S, np.int64)
        self._level_acc: Optional[np.ndarray] = None
        self._cold = 0

    def _n_starts(self) -> int:
        return self._starts.shape[0]

    def start_batches(self, config: ExecutorConfig):
        n, B = self._starts.shape[0], self._B
        for s0 in range(0, n, B):
            chunk = self._starts[s0:s0 + B]
            ids = np.full(B, self.sentinel, np.int32)
            ids[:chunk.shape[0]] = chunk
            valid = np.zeros(B, bool)
            valid[:chunk.shape[0]] = True
            yield ids, valid

    def initial_caps(self, config: ExecutorConfig) -> Tuple[int, ...]:
        return self._caps0

    def escalate_requests(self) -> None:
        self.req_cap *= 2

    def _runner(self, caps: Tuple[int, ...]) -> Callable:
        key = (self._cached_plan_ids, caps, self.req_cap, self._widths)
        if key not in self._runners:
            from .engine_sbenu_dist import build_sbenu_dist_step
            caps_list = [tuple(caps[lo:hi]) for lo, hi in self._offsets]
            self._runners[key] = build_sbenu_dist_step(
                self.plans, self.sentinel, self.spec, self.mesh,
                self._axis, caps_list, self.req_cap,
                rebalance=self._rebalance, collect_matches=self._collect,
                intersect_impl=self._intersect,
                compaction=self._compaction)
        return self._runners[key]

    def run_chunk(self, ids, valid, universe_chunk, caps) -> ChunkResult:
        import jax
        import jax.numpy as jnp
        jids = jax.device_put(jnp.asarray(ids), self._id_sharding)
        jvalid = jax.device_put(jnp.asarray(valid), self._id_sharding)
        out = self._runner(tuple(caps))(*self._block_args, jids, jvalid)
        cp, cm, ov, cold, drops, levels = out[:6]
        ov = int(np.sum(np.asarray(ov)))
        dr = int(np.sum(np.asarray(drops)))
        if ov or dr:
            # discard the whole mesh-wide chunk; the driver re-splits
            # (granularity S) or escalates caps / request budgets
            return ChunkResult(count=0, overflow=ov, drops=dr)
        cps = np.asarray(cp, np.int64)
        cms = np.asarray(cm, np.int64)
        self._per_shard += cps + cms
        self._cold += int(np.sum(np.asarray(cold)))
        lv = np.asarray(levels)
        self._level_acc = (lv if self._level_acc is None
                           else self._level_acc + lv)
        if self._collect:
            m, mo, mv = out[6:]
            mv = np.asarray(mv)
            rows = np.asarray(m)[mv]
            ops = np.asarray(mo)[mv]
            for row, o in zip(rows, ops):
                (self._plus if o > 0 else self._minus).append(
                    tuple(int(x) for x in row))
        self._count_plus += int(cps.sum())
        self._count_minus += int(cms.sum())
        return ChunkResult(count=int(cps.sum() + cms.sum()))

    def finalize(self, stats: ExecStats) -> None:
        from .sbenu import SBenuCounters
        ctr = SBenuCounters(matches_plus=self._count_plus,
                            matches_minus=self._count_minus)
        stats.extras.update(
            delta_plus=set(self._plus), delta_minus=set(self._minus),
            counters=ctr, per_shard_counts=self._per_shard,
            per_shard_level_sizes=(
                self._level_acc if self._level_acc is not None
                else np.zeros((0, self.S))),
            cold_rows_fetched=self._cold)


# --------------------------------------------------------------------------
# Factory + dry-run hook
# --------------------------------------------------------------------------


BACKENDS = {
    "ref": RefBackend,
    "jax": JaxBackend,
    "jax-gpu": JaxGpuBackend,
    "dist": DistBackend,
    "oocache": OocBackend,
    "sbenu": SBenuBackend,
    "sbenu-jax": SBenuJaxBackend,
    "sbenu-dist": SBenuDistBackend,
}


def make_executor(engine: str, **backend_kwargs) -> Executor:
    """``make_executor('dist', hot=64, rebalance=True).run(plan, graph)``."""
    try:
        cls = BACKENDS[engine]
    except KeyError:
        raise ValueError(
            f"unknown engine {engine!r}; choose from {sorted(BACKENDS)}")
    return Executor(cls(**backend_kwargs))


def build_benu_step(plan: Plan, spec, mesh, axis, caps: Sequence[int],
                    req_cap: int, rebalance: bool = True):
    """The distributed enumeration step the dry-run lowers for the BENU
    cell — the same step :class:`DistBackend` executes, exposed so
    launch/steps.py routes through the unified API."""
    from .engine_dist import build_distributed_step
    return build_distributed_step(plan, spec, mesh, axis, list(caps),
                                  req_cap, rebalance=rebalance)
