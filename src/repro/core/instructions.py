"""Execution-plan instructions (paper Table 3).

BENU (static, undirected):
    INI   f_i := Init(start)
    DBQ   A_i := GetAdj(f_i)
    INT   X   := Intersect(ops...)[| FCs]
    ENU   f_i := Foreach(X)
    TRC   X   := TCache(f_i, f_j, A_i, A_j)
    RES   f   := ReportMatch(f_1, ..)      (VCBC: some f_i replaced by C_i)

S-BENU additions (dynamic, directed):
    DBQ   A?? _i := GetAdj(f_i, type, dir, op)   type in {either,delta,unaltered}
    DENU  op, f_i := Foreach(X)                  (delta enumeration)
    INS   InSetTest(f_i, X)                      (back-edge existence test)

Variables are (kind, index) pairs. Kinds:
    'f'  mapped data vertex            'A'  adjacency set (BENU)
    'T'  intermediate intersection     'C'  candidate set
    'VG' the whole vertex set V(G)
    S-BENU adjacency kinds: 'AEI','AEO','ADI','ADO','AUI','AUO'
        (A + Either/Delta/Unaltered + In/Out)
Filter conditions are (op, var) with op in {'<', '>', '!='} comparing the
instruction's elements against ``f_var`` under the total order on V(G).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

Var = Tuple[str, int]          # e.g. ('A', 3), ('f', 0), ('VG', -1)
Filter = Tuple[str, Var]       # ('<', ('f', 2))

VG: Var = ("VG", -1)

INI, DBQ, INT, ENU, TRC, RES = "INI", "DBQ", "INT", "ENU", "TRC", "RES"
DENU, INS = "DENU", "INS"

# type rank used by Opt2 instruction reordering (paper §4.2.2)
TYPE_RANK = {INI: 0, INT: 1, TRC: 2, INS: 2, DBQ: 3, ENU: 4, DENU: 4, RES: 5}

SB_ADJ_KINDS = ("AEI", "AEO", "ADI", "ADO", "AUI", "AUO")


def var_name(v: Var) -> str:
    k, i = v
    return "V(G)" if k == "VG" else f"{k}{i + 1}"  # 1-based like the paper


@dataclass(frozen=True)
class Instr:
    op: str
    target: Optional[Var]                 # None for INS / RES
    operands: Tuple[Var, ...] = ()
    filters: Tuple[Filter, ...] = ()
    # DBQ (S-BENU): adjacency spec
    adj_type: Optional[str] = None        # either|delta|unaltered
    adj_dir: Optional[str] = None         # in|out
    adj_op: Optional[str] = None          # '+'|'-'|'*' (op-dependent snapshot)
    # RES payload: for VCBC, some entries are C-vars instead of f-vars
    report: Tuple[Var, ...] = ()

    def uses(self) -> Tuple[Var, ...]:
        """All variables this instruction reads (operands + filters + report).

        S-BENU: a DBQ with ``adj_op='op'`` reads the snapshot selector bound
        by the Delta-ENU, modeled as the pseudo-variable ``('op', -1)`` so the
        reorderer cannot hoist it above the Delta-ENU (cf. Fig. 6b).
        """
        vs = list(self.operands)
        vs += [v for _, v in self.filters]
        vs += list(self.report)
        if self.adj_op == "op":
            vs.append(("op", -1))
        return tuple(vs)

    def pretty(self) -> str:
        f = ""
        if self.filters:
            f = " | " + ", ".join(f"{op}{var_name(v)}" for op, v in self.filters)
        if self.op == INI:
            return f"{var_name(self.target)} := Init(start)"
        if self.op == DBQ:
            if self.adj_type is None:
                return f"{var_name(self.target)} := GetAdj({var_name(self.operands[0])})"
            return (f"{var_name(self.target)} := GetAdj("
                    f"{var_name(self.operands[0])},{self.adj_type},"
                    f"{self.adj_dir},{self.adj_op})")
        if self.op == INT:
            ops = ", ".join(var_name(v) for v in self.operands)
            return f"{var_name(self.target)} := Intersect({ops}){f}"
        if self.op == TRC:
            ops = ", ".join(var_name(v) for v in self.operands)
            return f"{var_name(self.target)} := TCache({ops}){f}"
        if self.op == ENU:
            return f"{var_name(self.target)} := Foreach({var_name(self.operands[0])})"
        if self.op == DENU:
            return (f"op,{var_name(self.target)} := "
                    f"Foreach({var_name(self.operands[0])})")
        if self.op == INS:
            return (f"InSetTest({var_name(self.operands[0])}, "
                    f"{var_name(self.operands[1])})")
        if self.op == RES:
            ops = ", ".join(var_name(v) for v in self.report)
            return f"f := ReportMatch({ops})"
        raise ValueError(self.op)


@dataclass
class Plan:
    """An ordered instruction list bound to a matching order."""

    pattern_name: str
    n: int
    matching_order: Tuple[int, ...]
    instrs: List[Instr]
    vcbc: bool = False
    core_k: int = 0                        # VCBC: first core_k of O are the cover
    constraints: Tuple[Tuple[int, int], ...] = ()   # symmetry partial order
    # S-BENU: which incremental pattern this plan enumerates (1-based), 0=BENU
    delta_edge: int = 0

    def pretty(self) -> str:
        hdr = (f"# plan for {self.pattern_name}, O="
               f"{[i + 1 for i in self.matching_order]}"
               + (f", VCBC core k={self.core_k}" if self.vcbc else "")
               + (f", dP_{self.delta_edge}" if self.delta_edge else ""))
        return "\n".join([hdr] + [f"{i:2d}: {ins.pretty()}"
                                  for i, ins in enumerate(self.instrs)])

    def count_ops(self) -> dict:
        c: dict = {}
        for ins in self.instrs:
            c[ins.op] = c.get(ins.op, 0) + 1
        return c

    def replace_instr(self, idx: int, new: Instr) -> None:
        self.instrs[idx] = new


def substitute(ins: Instr, old: Var, new: Var) -> Instr:
    """Replace variable ``old`` with ``new`` everywhere in ``ins``."""
    ops = tuple(new if v == old else v for v in ins.operands)
    flt = tuple((op, new if v == old else v) for op, v in ins.filters)
    rep = tuple(new if v == old else v for v in ins.report)
    return replace(ins, operands=ops, filters=flt, report=rep)
