"""Pattern graphs for (continuous) subgraph enumeration.

A :class:`Pattern` is a small, connected, simple graph. Undirected patterns
drive BENU; directed patterns drive S-BENU (edges carry a fixed numbering so
incremental pattern graphs are well defined).

Vertices are 0-based ints ``0..n-1`` (the paper uses 1-based ``u_1..u_n``).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import cached_property
from typing import Dict, FrozenSet, List, Sequence, Tuple

Edge = Tuple[int, int]


def _norm_undirected(e: Edge) -> Edge:
    a, b = e
    if a == b:
        raise ValueError(f"self loop {e} not allowed in a simple pattern")
    return (a, b) if a < b else (b, a)


@dataclass(frozen=True)
class Pattern:
    """A connected simple pattern graph.

    Parameters
    ----------
    n : number of vertices.
    edges : edge list. For undirected patterns the stored form is normalized
        to ``a < b``; for directed patterns the pair order is meaningful and
        the *position* in the tuple is the paper's edge id (1-based id = pos+1).
    directed : S-BENU patterns are directed; BENU patterns are undirected.
    name : optional label (q1..q9, q1'..q5', ...).
    """

    n: int
    edges: Tuple[Edge, ...]
    directed: bool = False
    name: str = ""

    def __post_init__(self):
        if self.n < 2:
            raise ValueError("pattern needs >= 2 vertices")
        es = list(self.edges)
        if not self.directed:
            es = [_norm_undirected(e) for e in es]
        seen = set()
        for e in es:
            if e in seen:
                raise ValueError(f"duplicate edge {e}")
            if self.directed and (e[0] == e[1]):
                raise ValueError(f"self loop {e}")
            seen.add(e)
            for v in e:
                if not (0 <= v < self.n):
                    raise ValueError(f"vertex {v} out of range 0..{self.n-1}")
        object.__setattr__(self, "edges", tuple(es))
        if not self.is_connected():
            raise ValueError(f"pattern {self.name or es} must be connected")

    # ------------------------------------------------------------------ basic
    @property
    def m(self) -> int:
        return len(self.edges)

    @cached_property
    def undirected_edges(self) -> Tuple[Edge, ...]:
        """Edge set viewed undirected (dedup of anti-parallel pairs)."""
        return tuple(sorted({_norm_undirected(e) for e in self.edges}))

    @cached_property
    def adj(self) -> Tuple[FrozenSet[int], ...]:
        """Undirected adjacency (union of in/out for directed patterns)."""
        nbr: List[set] = [set() for _ in range(self.n)]
        for a, b in self.edges:
            nbr[a].add(b)
            nbr[b].add(a)
        return tuple(frozenset(s) for s in nbr)

    @cached_property
    def adj_out(self) -> Tuple[FrozenSet[int], ...]:
        nbr: List[set] = [set() for _ in range(self.n)]
        for a, b in self.edges:
            nbr[a].add(b)
        return tuple(frozenset(s) for s in nbr)

    @cached_property
    def adj_in(self) -> Tuple[FrozenSet[int], ...]:
        nbr: List[set] = [set() for _ in range(self.n)]
        for a, b in self.edges:
            nbr[b].add(a)
        return tuple(frozenset(s) for s in nbr)

    def degree(self, v: int) -> int:
        return len(self.adj[v])

    def is_connected(self) -> bool:
        seen = {0}
        stack = [0]
        nbr: List[set] = [set() for _ in range(self.n)]
        for a, b in self.edges:
            nbr[a].add(b)
            nbr[b].add(a)
        while stack:
            v = stack.pop()
            for w in nbr[v]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == self.n

    def has_edge(self, a: int, b: int) -> bool:
        if self.directed:
            return (a, b) in self._edge_set
        return _norm_undirected((a, b)) in self._edge_set

    @cached_property
    def _edge_set(self) -> FrozenSet[Edge]:
        return frozenset(self.edges)

    # -------------------------------------------------------------- morphisms
    @cached_property
    def automorphisms(self) -> Tuple[Tuple[int, ...], ...]:
        """All automorphisms as permutation tuples ``perm[u] = image of u``.

        Brute-force backtracking with degree pruning — patterns are tiny
        (n <= 10 in the paper's experiments).
        """
        deg = [self.degree(v) for v in range(self.n)]
        # group vertices by degree for candidate pruning
        out: List[Tuple[int, ...]] = []
        perm = [-1] * self.n
        used = [False] * self.n

        if self.directed:
            indeg = [len(self.adj_in[v]) for v in range(self.n)]
            outdeg = [len(self.adj_out[v]) for v in range(self.n)]

        def ok(u: int, img: int) -> bool:
            if deg[u] != deg[img]:
                return False
            if self.directed and (
                len(self.adj_in[u]) != len(self.adj_in[img])
                or len(self.adj_out[u]) != len(self.adj_out[img])
            ):
                return False
            # check edges to already-mapped vertices
            for w in range(self.n):
                if perm[w] < 0 or w == u:
                    continue
                if self.directed:
                    if ((u, w) in self._edge_set) != ((img, perm[w]) in self._edge_set):
                        return False
                    if ((w, u) in self._edge_set) != ((perm[w], img) in self._edge_set):
                        return False
                else:
                    if self.has_edge(u, w) != self.has_edge(img, perm[w]):
                        return False
            return True

        def rec(u: int):
            if u == self.n:
                out.append(tuple(perm))
                return
            for img in range(self.n):
                if used[img] or not ok(u, img):
                    continue
                perm[u] = img
                used[img] = True
                rec(u + 1)
                perm[u] = -1
                used[img] = False

        rec(0)
        return tuple(out)

    # ------------------------------------------------ syntactic equivalence
    def syntactic_equivalent(self, a: int, b: int) -> bool:
        """``u_a ~= u_b`` iff Gamma(a) - {b} == Gamma(b) - {a} (paper 4.3.2)."""
        if self.directed:
            raise ValueError("use IncrementalPattern.syntactic_equivalent")
        return (self.adj[a] - {b}) == (self.adj[b] - {a})

    def se_pairs(self) -> List[Tuple[int, int]]:
        return [
            (a, b)
            for a in range(self.n)
            for b in range(a + 1, self.n)
            if self.syntactic_equivalent(a, b)
        ]

    # ----------------------------------------------------------------- misc
    def induced(self, vertices: Sequence[int]) -> "Pattern":
        vs = list(vertices)
        remap = {v: i for i, v in enumerate(vs)}
        es = [
            (remap[a], remap[b])
            for a, b in self.edges
            if a in remap and b in remap
        ]
        return Pattern(len(vs), tuple(es), directed=self.directed,
                       name=f"{self.name}[{vs}]")

    def is_vertex_cover(self, vs: Sequence[int]) -> bool:
        s = set(vs)
        return all(a in s or b in s for a, b in self.undirected_edges)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        kind = "DiPattern" if self.directed else "Pattern"
        return f"{kind}({self.name or ''} n={self.n} edges={list(self.edges)})"


# ---------------------------------------------------------------------------
# Pattern library.
#
# Fig. 8 of the paper is an image (not machine-readable in our source). q1-q5
# follow the CBF paper (Qiao et al., PVLDB'17) which the authors cite as the
# origin of q1..q5; q6-q9 are "hard" patterns sharing a chordal-square core as
# the text describes. The Fig.1 running-example pattern is reconstructed
# exactly from the textual clues (fan F5: hub u1 + path u2-u3-u4-u5-u6;
# automorphism (u2 u6)(u3 u5); symmetry constraint u3 < u5; CSE finds
# {A1,A3} and {A1,A5} for order u1,u3,u5,u2,u6,u4).
# ---------------------------------------------------------------------------


def _p(n: int, edges: Sequence[Edge], name: str) -> Pattern:
    return Pattern(n, tuple(edges), directed=False, name=name)


TRIANGLE = _p(3, [(0, 1), (1, 2), (0, 2)], "triangle")
SQUARE = _p(4, [(0, 1), (1, 2), (2, 3), (0, 3)], "square")  # 4-cycle
CHORDAL_SQUARE = _p(4, [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)], "chordal-square")
CLIQUE4 = _p(4, list(itertools.combinations(range(4), 2)), "clique4")
CLIQUE5 = _p(5, list(itertools.combinations(range(5), 2)), "clique5")
PATH5 = _p(5, [(0, 1), (1, 2), (2, 3), (3, 4)], "path5")       # 5-path
CYCLE5 = _p(5, [(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)], "cycle5")  # 5-cycle
HOUSE = _p(5, [(0, 1), (1, 2), (2, 3), (3, 4), (0, 4), (0, 1), ][:5] + [], "house")
# house = square + roof triangle
HOUSE = _p(5, [(0, 1), (1, 2), (2, 3), (0, 3), (0, 4), (1, 4)], "house")
# fan F5 = running example of Fig.1 (hub 0, path 1-2-3-4-5)
FAN5 = _p(
    6,
    [(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 2), (2, 3), (3, 4), (4, 5)],
    "fan5",
)

# Benchmark pattern set (paper Fig. 8). q1..q5 from CBF; q6..q9 hard patterns
# around a chordal-square core.
Q1 = _p(4, SQUARE.edges, "q1")
Q2 = _p(4, CHORDAL_SQUARE.edges, "q2")
Q3 = _p(4, CLIQUE4.edges, "q3")
Q4 = _p(5, HOUSE.edges, "q4")
Q5 = _p(5, CLIQUE5.edges, "q5")
# q6: chordal square + pendant path ("tailed diamond")
Q6 = _p(5, list(CHORDAL_SQUARE.edges) + [(3, 4)], "q6")
# q7: chordal square core + a vertex adjacent to two opposite core vertices
Q7 = _p(5, list(CHORDAL_SQUARE.edges) + [(1, 4), (3, 4)], "q7")
# q8: chordal square core + triangle hanging off the chord
Q8 = _p(6, list(CHORDAL_SQUARE.edges) + [(0, 4), (2, 4), (0, 5), (4, 5)], "q8")
# q9: two chordal squares sharing the chord
Q9 = _p(6, list(CHORDAL_SQUARE.edges) + [(0, 4), (2, 4), (0, 5), (2, 5)], "q9")

UNDIRECTED_PATTERNS: Dict[str, Pattern] = {
    p.name: p
    for p in [
        TRIANGLE, SQUARE, CHORDAL_SQUARE, CLIQUE4, CLIQUE5, PATH5, CYCLE5,
        HOUSE, FAN5, Q1, Q2, Q3, Q4, Q5, Q6, Q7, Q8, Q9,
    ]
}


def _dp(n: int, edges: Sequence[Edge], name: str) -> Pattern:
    return Pattern(n, tuple(edges), directed=True, name=name)


# S-BENU patterns q1'..q5' follow BiGJoin's dynamic queries (directed cycles /
# small DAG motifs).
DQ1 = _dp(3, [(0, 1), (1, 2), (2, 0)], "q1'")  # directed triangle cycle
DQ2 = _dp(4, [(0, 1), (1, 2), (2, 3), (3, 0)], "q2'")  # directed 4-cycle
DQ3 = _dp(4, [(0, 1), (1, 2), (2, 0), (0, 3), (3, 1)], "q3'")  # tri + 2-path chord
DQ4 = _dp(5, [(0, 1), (1, 2), (2, 0), (0, 3), (3, 4), (4, 0)], "q4'")  # two cycles
DQ5 = _dp(4, [(0, 1), (0, 2), (0, 3), (1, 2), (2, 3), (1, 3)], "q5'")  # DAG K4
# Fig.5 running example of the dynamic section: directed triangle u1->u3,
# u3->u2 ... the paper's DeltaP_2 demo uses edges e1=(u1,u2), e2=(u1,u3),
# e3=(u2,u3) with O_2: u1,u3,u2.
DTOY = _dp(3, [(0, 1), (0, 2), (1, 2)], "dtoy")

DIRECTED_PATTERNS: Dict[str, Pattern] = {
    p.name: p for p in [DQ1, DQ2, DQ3, DQ4, DQ5, DTOY]
}


def get_pattern(name: str) -> Pattern:
    if name in UNDIRECTED_PATTERNS:
        return UNDIRECTED_PATTERNS[name]
    if name in DIRECTED_PATTERNS:
        return DIRECTED_PATTERNS[name]
    raise KeyError(f"unknown pattern {name!r}; have "
                   f"{sorted(UNDIRECTED_PATTERNS) + sorted(DIRECTED_PATTERNS)}")
