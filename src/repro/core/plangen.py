"""BENU execution-plan generation (paper §4).

Pipeline::

    matching order O
      -> raw plan                      (§4.1)
      -> Opt1 common-subexpr elim      (§4.2.1)
      -> Opt2 instruction reordering   (§4.2.2)
      -> Opt3 triangle caching         (§4.2.3)
      -> (optional) VCBC compression   (§4.2.4)

and the best-plan search (Alg. 3) with dual pruning + cost-based pruning.
"""

from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .estimate import DEFAULT_STATS, GraphStats, PartialPatternTracker
from .instructions import (DBQ, ENU, INI, INT, RES, TRC, TYPE_RANK, VG, Instr,
                           Plan, Var, substitute)
from .pattern import Pattern
from .symmetry import symmetry_breaking_constraints

# --------------------------------------------------------------------------
# Raw plan generation (§4.1)
# --------------------------------------------------------------------------


def generate_raw_plan(pattern: Pattern,
                      order: Sequence[int],
                      constraints: Optional[Sequence[Tuple[int, int]]] = None,
                      keep: FrozenSet[Var] = frozenset(),
                      eliminate: bool = True) -> Plan:
    """Generate the raw execution plan for matching order ``order``.

    ``constraints`` are symmetry-breaking pairs (a, b) == f_a < f_b; computed
    from the pattern when omitted. ``keep`` marks target vars protected from
    uni-operand elimination (VCBC outputs).
    """
    if sorted(order) != list(range(pattern.n)):
        raise ValueError(f"order {order} is not a permutation of V(P)")
    if constraints is None:
        constraints = symmetry_breaking_constraints(pattern)
    cons = set(map(tuple, constraints))
    pos = {u: i for i, u in enumerate(order)}
    k1 = order[0]

    instrs: List[Instr] = [Instr(INI, ("f", k1))]
    if any(pos[w] > 0 for w in pattern.adj[k1]):
        instrs.append(Instr(DBQ, ("A", k1), operands=(("f", k1),)))

    for i in range(1, pattern.n):
        u = order[i]
        preds = sorted((w for w in pattern.adj[u] if pos[w] < i),
                       key=lambda w: pos[w])
        ops: Tuple[Var, ...] = tuple(("A", w) for w in preds) or (VG,)
        instrs.append(Instr(INT, ("T", u), operands=ops))
        fcs: List[Tuple[str, Var]] = []
        for j in order[:i]:
            if (j, u) in cons:
                fcs.append((">", ("f", j)))      # f_u must be > f_j
            elif (u, j) in cons:
                fcs.append(("<", ("f", j)))
            elif j not in pattern.adj[u]:
                fcs.append(("!=", ("f", j)))      # injectivity (adjacency implies !=)
        instrs.append(Instr(INT, ("C", u), operands=(("T", u),),
                            filters=tuple(fcs)))
        instrs.append(Instr(ENU, ("f", u), operands=(("C", u),)))
        if any(pos[w] > i for w in pattern.adj[u]):
            instrs.append(Instr(DBQ, ("A", u), operands=(("f", u),)))

    instrs.append(Instr(RES, None,
                        report=tuple(("f", u) for u in range(pattern.n))))

    plan = Plan(pattern_name=pattern.name, n=pattern.n,
                matching_order=tuple(order), instrs=instrs,
                constraints=tuple(sorted(cons)))
    if eliminate:
        uni_operand_elimination(plan, keep)
    return plan


def uni_operand_elimination(plan: Plan, keep: FrozenSet[Var] = frozenset()
                            ) -> None:
    """Remove ``X := Intersect(Y)`` with no filters; rename X -> Y (§4.1.2)."""
    changed = True
    while changed:
        changed = False
        for idx, ins in enumerate(plan.instrs):
            if (ins.op == INT and len(ins.operands) == 1 and not ins.filters
                    and ins.target not in keep):
                src = ins.operands[0]
                tgt = ins.target
                del plan.instrs[idx]
                plan.instrs[:] = [substitute(other, tgt, src)
                                  for other in plan.instrs]
                changed = True
                break


# --------------------------------------------------------------------------
# Opt1: common-subexpression elimination (§4.2.1)
# --------------------------------------------------------------------------


def _subexpr_stats(plan: Plan) -> Dict[FrozenSet[Var], Tuple[int, int]]:
    """All operand subsets (|s| >= 2) of INT instructions -> (count, first_idx)."""
    stats: Dict[FrozenSet[Var], Tuple[int, int]] = {}
    for idx, ins in enumerate(plan.instrs):
        if ins.op != INT or len(ins.operands) < 2:
            continue
        opset = list(dict.fromkeys(ins.operands))
        for r in range(2, len(opset) + 1):
            for sub in itertools.combinations(opset, r):
                key = frozenset(sub)
                cnt, first = stats.get(key, (0, idx))
                stats[key] = (cnt + 1, min(first, idx))
    return stats


def _fresh_t_index(plan: Plan) -> int:
    used = {v[1] for ins in plan.instrs
            for v in (ins.target,) + ins.uses() if v and v[0] == "T"}
    used |= set(range(plan.n))
    i = plan.n
    while i in used:
        i += 1
    return i


def common_subexpression_elimination(plan: Plan,
                                     keep: FrozenSet[Var] = frozenset()
                                     ) -> int:
    """Opt1. Returns the number of subexpressions eliminated."""
    eliminated = 0
    while True:
        stats = _subexpr_stats(plan)
        cands = [(len(k), cnt, -first, k)
                 for k, (cnt, first) in stats.items() if cnt >= 2]
        if not cands:
            break
        # most operands, then most frequent, then appearing first
        cands.sort(key=lambda t: (-t[0], -t[1], t[2]))
        size, cnt, negfirst, sub = cands[0]
        first_idx = -negfirst
        tvar: Var = ("T", _fresh_t_index(plan))
        new = Instr(INT, tvar, operands=tuple(
            sorted(sub, key=lambda v: _def_index(plan, v))))
        # rewrite users
        for idx, ins in enumerate(plan.instrs):
            if ins.op == INT and sub <= set(ins.operands):
                ops = tuple(v for v in ins.operands if v not in sub) + (tvar,)
                plan.instrs[idx] = replace(ins, operands=ops)
        plan.instrs.insert(first_idx, new)
        eliminated += 1
    uni_operand_elimination(plan, keep)
    return eliminated


def _def_index(plan: Plan, v: Var) -> int:
    if v[0] == "VG":
        return -1
    for idx, ins in enumerate(plan.instrs):
        if ins.target == v:
            return idx
    return -1  # undefined (e.g. being inserted) sorts first


# --------------------------------------------------------------------------
# Opt2: instruction reordering (§4.2.2)
# --------------------------------------------------------------------------


def flatten_intersections(plan: Plan) -> None:
    """Flatten INT instructions with > 2 operands into binary chains."""
    out: List[Instr] = []
    for ins in plan.instrs:
        if ins.op == INT and len(ins.operands) > 2:
            ops = sorted(ins.operands, key=lambda v: _def_index(plan, v))
            acc = ops[0]
            for j, nxt in enumerate(ops[1:]):
                last = j == len(ops) - 2
                if last:
                    out.append(replace(ins, operands=(acc, nxt)))
                else:
                    tv: Var = ("T", _fresh_t_index_from(out, plan))
                    out.append(Instr(INT, tv, operands=(acc, nxt)))
                    acc = tv
        else:
            out.append(ins)
    plan.instrs[:] = out


def _fresh_t_index_from(extra: List[Instr], plan: Plan) -> int:
    used = {v[1] for ins in list(plan.instrs) + extra
            for v in (ins.target,) + ins.uses() if v and v[0] == "T"}
    used |= set(range(plan.n))
    i = plan.n
    while i in used:
        i += 1
    return i


def reorder_instructions(plan: Plan) -> None:
    """Opt2: dependency-graph topological sort with type ranking.

    Rank: INI < INT < TRC/INS < DBQ < ENU < RES; ties -> original position
    (the paper: "the instruction in the front ranks higher").
    """
    flatten_intersections(plan)
    n = len(plan.instrs)
    defs: Dict[Var, int] = {}
    for idx, ins in enumerate(plan.instrs):
        if ins.target is not None:
            defs[ins.target] = idx
        if ins.op == "DENU":          # Delta-ENU binds the snapshot selector
            defs[("op", -1)] = idx
    preds: List[Set[int]] = [set() for _ in range(n)]
    succs: List[Set[int]] = [set() for _ in range(n)]
    for idx, ins in enumerate(plan.instrs):
        for v in ins.uses():
            if v in defs and defs[v] != idx:
                preds[idx].add(defs[v])
                succs[defs[v]].add(idx)
        # RES depends on everything that defines a reported var (covered by
        # uses()); additionally keep RES last by rank.
    indeg = [len(p) for p in preds]
    heap = [(TYPE_RANK[plan.instrs[i].op], i)
            for i in range(n) if indeg[i] == 0]
    heapq.heapify(heap)
    order: List[int] = []
    while heap:
        _, i = heapq.heappop(heap)
        order.append(i)
        for j in succs[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                heapq.heappush(heap, (TYPE_RANK[plan.instrs[j].op], j))
    if len(order) != n:
        raise RuntimeError("cycle in instruction dependency graph")
    plan.instrs[:] = [plan.instrs[i] for i in order]


# --------------------------------------------------------------------------
# Opt3: triangle caching (§4.2.3)
# --------------------------------------------------------------------------


def apply_triangle_cache(plan: Plan, pattern: Pattern) -> int:
    """Replace ``X := Intersect(A_k1, A_j)`` by a TCache instruction when u_j
    is a pattern-neighbor of the start vertex u_k1. Returns #replaced."""
    k1 = plan.matching_order[0]
    count = 0
    for idx, ins in enumerate(plan.instrs):
        if ins.op != INT or len(ins.operands) != 2:
            continue
        a, b = ins.operands
        if a[0] != "A" or b[0] != "A":
            continue
        i, j = a[1], b[1]
        if i == k1 and j in pattern.adj[k1] or j == k1 and i in pattern.adj[k1]:
            plan.instrs[idx] = replace(
                ins, op=TRC,
                operands=(("f", i), ("f", j), ("A", i), ("A", j)))
            count += 1
    return count


# --------------------------------------------------------------------------
# Optimized plan assembly
# --------------------------------------------------------------------------


def generate_optimized_plan(pattern: Pattern,
                            order: Sequence[int],
                            constraints: Optional[Sequence[Tuple[int, int]]]
                            = None,
                            use_cse: bool = True,
                            use_reorder: bool = True,
                            use_trc: bool = True,
                            vcbc: bool = False) -> Plan:
    keep: FrozenSet[Var] = frozenset()
    core_k = 0
    if vcbc:
        core_k = _vcbc_core_k(pattern, order)
        keep = frozenset(("C", u) for u in order[core_k:])
    plan = generate_raw_plan(pattern, order, constraints, keep=keep)
    if use_cse:
        common_subexpression_elimination(plan, keep)
    if use_reorder:
        reorder_instructions(plan)
    if use_trc:
        apply_triangle_cache(plan, pattern)
    if vcbc:
        from .vcbc import compress_plan  # local import to avoid cycle
        compress_plan(plan, pattern, core_k)
        if use_reorder:
            reorder_instructions(plan)
    return plan


def _vcbc_core_k(pattern: Pattern, order: Sequence[int]) -> int:
    for k in range(1, pattern.n + 1):
        if pattern.is_vertex_cover(order[:k]):
            return k
    return pattern.n


# --------------------------------------------------------------------------
# Cost estimation over a plan (paper Alg. 3 ESTIMATECOMPUTATIONCOST)
# --------------------------------------------------------------------------


def estimate_computation_cost(pattern: Pattern, plan: Plan,
                              stats: GraphStats = DEFAULT_STATS) -> float:
    """#executions of INT/TRC instructions under the cardinality model.

    Deviation from the paper's pseudo-code (documented): INI also updates the
    partial pattern, so instructions hoisted before the first ENU are costed
    once-per-task (|V(G)| times) instead of zero — the pseudo-code initializes
    curNum to 0 which under-counts hoisted instructions; semantics in §4.3.1
    ("instructions between the i-th and i+1-th ENU execute as often as the
    i-th ENU") imply our reading.
    """
    tracker = PartialPatternTracker(pattern, stats, plan.delta_edge)
    cur = 0.0
    cost = 0.0
    for ins in plan.instrs:
        if ins.op in (INI, ENU, "DENU"):
            tracker.add_vertex(ins.target[1])
            cur = tracker.estimate()
        elif ins.op in (INT, TRC, "INS"):
            cost += cur
    return cost


def estimate_communication_cost(pattern: Pattern, plan: Plan,
                                stats: GraphStats = DEFAULT_STATS) -> float:
    """#executions of DBQ instructions under the cardinality model."""
    tracker = PartialPatternTracker(pattern, stats, plan.delta_edge)
    cur = 0.0
    cost = 0.0
    for ins in plan.instrs:
        if ins.op in (INI, ENU, "DENU"):
            tracker.add_vertex(ins.target[1])
            cur = tracker.estimate()
        elif ins.op == DBQ:
            cost += cur
    return cost


# --------------------------------------------------------------------------
# Best execution plan search (paper Alg. 3)
# --------------------------------------------------------------------------


def _se_classes(pattern: Pattern) -> List[List[int]]:
    cls: List[List[int]] = []
    assigned = [False] * pattern.n
    for a in range(pattern.n):
        if assigned[a]:
            continue
        group = [a]
        assigned[a] = True
        for b in range(a + 1, pattern.n):
            if not assigned[b] and pattern.syntactic_equivalent(a, b):
                group.append(b)
                assigned[b] = True
        cls.append(group)
    return cls


class SearchResult:
    def __init__(self):
        self.best_comm = float("inf")
        self.candidates: List[Tuple[int, ...]] = []
        self.orders_explored = 0
        self.orders_total = 0


def search_matching_orders(pattern: Pattern,
                           stats: GraphStats = DEFAULT_STATS,
                           fixed_prefix: Tuple[int, ...] = (),
                           delta_edge: int = 0,
                           max_candidates: int = 256,
                           se_classes: Optional[List[List[int]]] = None
                           ) -> SearchResult:
    """SEARCH procedure of Alg. 3: candidate orders minimizing comm cost.

    ``fixed_prefix`` pins the first vertices (S-BENU pins (u_si, u_ti)).
    ``delta_edge`` feeds the S-BENU delta-aware cardinality model.
    ``se_classes`` overrides the syntactic-equivalence classes used for dual
    pruning (S-BENU's stricter typed/directed condition, paper §5.4).
    """
    if se_classes is not None:
        se = se_classes
    else:
        se = _se_classes(pattern) if not pattern.directed else None
    # for dual pruning: smaller-id SE sibling must be placed first
    se_pred: Dict[int, List[int]] = {v: [] for v in range(pattern.n)}
    if se is not None:
        for group in se:
            for i, v in enumerate(group[1:], start=1):
                se_pred[v] = group[:i]

    res = SearchResult()
    import math
    res.orders_total = math.factorial(pattern.n - len(fixed_prefix))

    def has_later_neighbor(u: int, placed: Set[int]) -> bool:
        return any(w not in placed and w != u for w in pattern.adj[u])

    def search(order: List[int], remaining: Set[int],
               tracker: PartialPatternTracker, comm: float) -> None:
        if not remaining:
            res.orders_explored += 1
            if comm < res.best_comm - 1e-12:
                res.best_comm = comm
                res.candidates = [tuple(order)]
            elif abs(comm - res.best_comm) <= 1e-12 * max(1.0, comm):
                if len(res.candidates) < max_candidates:
                    res.candidates.append(tuple(order))
            return
        for u in sorted(remaining):
            if se_pred is not None and any(p in remaining for p in se_pred[u]
                                           if p != u):
                continue  # dual pruning
            t2 = tracker.clone()
            t2.add_vertex(u)
            placed = set(order) | {u}
            if has_later_neighbor(u, placed):
                s = t2.estimate()          # case 1: a DBQ will be generated
            else:
                s = 0.0                    # case 2
            comm2 = comm + s
            if comm2 > res.best_comm * (1 + 1e-12):
                continue                   # cost-based pruning
            order.append(u)
            remaining.discard(u)
            search(order, remaining, t2, comm2)
            order.pop()
            remaining.add(u)

    tracker = PartialPatternTracker(pattern, stats, delta_edge)
    order = list(fixed_prefix)
    comm = 0.0
    for u in fixed_prefix:
        tracker.add_vertex(u)
        placed = set(order[:order.index(u) + 1]) if u in order else set(order)
    # recompute comm contributions of the fixed prefix
    tracker = PartialPatternTracker(pattern, stats, delta_edge)
    comm = 0.0
    for i, u in enumerate(fixed_prefix):
        tracker.add_vertex(u)
        placed = set(fixed_prefix[:i + 1])
        if has_later_neighbor(u, placed):
            comm += tracker.estimate()
    remaining = set(range(pattern.n)) - set(fixed_prefix)
    search(list(fixed_prefix), remaining, tracker, comm)
    return res


def generate_best_plan(pattern: Pattern,
                       stats: GraphStats = DEFAULT_STATS,
                       vcbc: bool = False,
                       use_cse: bool = True,
                       use_reorder: bool = True,
                       use_trc: bool = True) -> Plan:
    """Alg. 3: best plan = min comm cost, ties by min computation cost."""
    sr = search_matching_orders(pattern, stats)
    best_plan: Optional[Plan] = None
    best_cost = float("inf")
    for order in sr.candidates:
        plan = generate_optimized_plan(pattern, order, vcbc=vcbc,
                                       use_cse=use_cse,
                                       use_reorder=use_reorder,
                                       use_trc=use_trc)
        cost = estimate_computation_cost(pattern, plan, stats)
        if cost < best_cost:
            best_cost = cost
            best_plan = plan
    assert best_plan is not None
    return best_plan
