"""Reference (oracle) executor for BENU plans — pure Python.

Faithfully interprets an execution plan the way the paper's workers do:
local search tasks per start vertex, adjacency queries against a (cached)
database, triangle cache per task, optional task splitting. Used as the
correctness oracle for the JAX engines and as the counting model for the
Fig. 9 / Fig. 10 / Fig. 11 reproductions.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from ..graph.storage import Graph
from .instructions import (DBQ, ENU, INI, INT, RES, TRC, Instr, Plan, Var)
from .pattern import Pattern


# --------------------------------------------------------------------------
# Database with LRU cache (paper §6.1)
# --------------------------------------------------------------------------


class GraphDB:
    """Adjacency database with an optional LRU row cache.

    ``cache_capacity`` counts rows (the paper's capacity is bytes relative to
    graph size; benchmarks convert). ``remote_queries`` counts misses — the
    communication cost in the paper's model.
    """

    def __init__(self, graph: Graph, cache_capacity: Optional[int] = None):
        self.graph = graph
        self.capacity = cache_capacity
        self.cache: "OrderedDict[int, frozenset]" = OrderedDict()
        self.total_queries = 0
        self.remote_queries = 0

    def get_adj(self, v: int) -> frozenset:
        self.total_queries += 1
        if self.capacity is not None:
            hit = self.cache.get(v)
            if hit is not None:
                self.cache.move_to_end(v)
                return hit
        self.remote_queries += 1
        row = frozenset(int(w) for w in self.graph.adj[v])
        if self.capacity is not None and self.capacity > 0:
            self.cache[v] = row
            if len(self.cache) > self.capacity:
                self.cache.popitem(last=False)
        return row

    @property
    def hit_rate(self) -> float:
        if self.total_queries == 0:
            return 0.0
        return 1.0 - self.remote_queries / self.total_queries


# --------------------------------------------------------------------------
# Counters
# --------------------------------------------------------------------------


@dataclass
class Counters:
    dbq: int = 0
    int_: int = 0
    trc: int = 0
    trc_hits: int = 0
    enu: int = 0
    matches: int = 0
    per_task_work: List[int] = field(default_factory=list)

    def merge(self, other: "Counters") -> None:
        self.dbq += other.dbq
        self.int_ += other.int_
        self.trc += other.trc
        self.trc_hits += other.trc_hits
        self.enu += other.enu
        self.matches += other.matches
        self.per_task_work.extend(other.per_task_work)

    @property
    def computation_cost(self) -> int:
        return self.int_ + self.trc

    @property
    def communication_cost(self) -> int:
        return self.dbq


# --------------------------------------------------------------------------
# Task generation + splitting (paper §3.1, §6.3)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Task:
    start: int
    c2_slice: Optional[Tuple[int, int]] = None   # (begin, end) into sorted C2


def tasks_for_starts(plan: Plan, pattern: Pattern, graph: Graph,
                     starts: Iterable[int],
                     theta: Optional[int] = None) -> List[Task]:
    """Local search tasks for ``starts``; heavy tasks split by θ into C2
    slices. The single task-split rule shared by RefEngine.run and the
    unified Executor's ref backend."""
    k1, k2 = plan.matching_order[:2]
    adjacent12 = k2 in pattern.adj[k1]
    tasks: List[Task] = []
    for v in starts:
        v = int(v)
        base = int(graph.deg[v]) if adjacent12 else graph.n
        if theta is not None and base > theta:
            n_sub = -(-base // theta)
            for s in range(n_sub):
                tasks.append(Task(v, (s * theta, min((s + 1) * theta, base))))
        else:
            tasks.append(Task(v))
    return tasks


def make_tasks(plan: Plan, graph: Graph,
               theta: Optional[int] = None) -> List[Task]:
    """One task per data vertex; heavy tasks split by degree threshold θ."""
    order = plan.matching_order
    k1, k2 = order[0], order[1]
    adjacent12 = k2 in _pattern_adj(plan, k1)
    tasks: List[Task] = []
    for v in range(graph.n):
        base = int(graph.deg[v]) if adjacent12 else graph.n
        if theta is not None and base > theta:
            n_sub = -(-base // theta)
            for s in range(n_sub):
                tasks.append(Task(v, (s * theta, min((s + 1) * theta, base))))
        else:
            tasks.append(Task(v))
    return tasks


def _pattern_adj(plan: Plan, u: int) -> Set[int]:
    # reconstruct u's pattern neighbours from the plan's raw structure: the
    # ENU of order[1] consumes a set derived from A_{k1} iff adjacent. We
    # instead thread the pattern through execute(); this helper is only used
    # by make_tasks when the pattern is unavailable.
    return set(range(plan.n))  # conservative: treat as adjacent


# --------------------------------------------------------------------------
# Plan interpreter
# --------------------------------------------------------------------------


class RefEngine:
    """Interprets a BENU plan over a Graph. Oracle for the JAX engines."""

    def __init__(self, plan: Plan, pattern: Pattern, graph: Graph,
                 db: Optional[GraphDB] = None,
                 collect: str = "count"):
        """``collect``: 'count' | 'matches' | 'codes' (VCBC)."""
        self.plan = plan
        self.pattern = pattern
        self.graph = graph
        self.db = db or GraphDB(graph)
        self.collect = collect
        self.matches: List[Tuple[int, ...]] = []
        self.codes: List[Dict[Var, object]] = []
        self.counters = Counters()
        # resolve the ENU instruction of the 2nd matching-order vertex for
        # task splitting
        self._second_enu_idx = None
        tgt = ("f", plan.matching_order[1]) if plan.n >= 2 else None
        for i, ins in enumerate(plan.instrs):
            if ins.op == ENU and ins.target == tgt:
                self._second_enu_idx = i
                break

    # ---------------------------------------------------------------- public
    def run(self, tasks: Optional[Sequence[Task]] = None,
            theta: Optional[int] = None) -> Counters:
        if tasks is None:
            tasks = tasks_for_starts(self.plan, self.pattern, self.graph,
                                     range(self.graph.n), theta=theta)
        for task in tasks:
            self._run_task(task)
        return self.counters

    # --------------------------------------------------------------- internal
    def _run_task(self, task: Task) -> None:
        env: Dict[Var, object] = {}
        tcache: Dict[Tuple[int, int], frozenset] = {}
        work_before = self.counters.int_ + self.counters.trc + self.counters.enu
        self._exec(0, env, task, tcache)
        self.counters.per_task_work.append(
            self.counters.int_ + self.counters.trc + self.counters.enu
            - work_before)

    def _apply_filters(self, values: Iterable[int], filters,
                       env: Dict[Var, object]) -> frozenset:
        out = []
        for x in values:
            ok = True
            for op, var in filters:
                fv = env[var]
                if op == "<" and not x < fv:
                    ok = False
                elif op == ">" and not x > fv:
                    ok = False
                elif op == "!=" and x == fv:
                    ok = False
                if not ok:
                    break
            if ok:
                out.append(x)
        return frozenset(out)

    def _operand_set(self, var: Var, env: Dict[Var, object]) -> frozenset:
        if var[0] == "VG":
            return frozenset(range(self.graph.n))
        return env[var]  # type: ignore

    def _exec(self, ip: int, env: Dict[Var, object], task: Task,
              tcache: Dict[Tuple[int, int], frozenset]) -> None:
        if ip >= len(self.plan.instrs):
            return
        ins = self.plan.instrs[ip]
        op = ins.op
        if op == INI:
            env[ins.target] = task.start
            self._exec(ip + 1, env, task, tcache)
        elif op == DBQ:
            v = env[ins.operands[0]]
            env[ins.target] = self.db.get_adj(v)  # type: ignore
            self.counters.dbq += 1
            self._exec(ip + 1, env, task, tcache)
        elif op == INT:
            self.counters.int_ += 1
            sets = [self._operand_set(v, env) for v in ins.operands]
            sets.sort(key=len)
            acc = sets[0]
            for s in sets[1:]:
                acc = acc & s
            if ins.filters:
                acc = self._apply_filters(acc, ins.filters, env)
            env[ins.target] = acc
            self._exec(ip + 1, env, task, tcache)
        elif op == TRC:
            self.counters.trc += 1
            fi, fj = env[ins.operands[0]], env[ins.operands[1]]
            key = (fi, fj)  # type: ignore
            hit = tcache.get(key)
            if hit is None:
                ai = self._operand_set(ins.operands[2], env)
                aj = self._operand_set(ins.operands[3], env)
                hit = ai & aj
                tcache[key] = hit
            else:
                self.counters.trc_hits += 1
            if ins.filters:
                hit = self._apply_filters(hit, ins.filters, env)
            env[ins.target] = hit
            self._exec(ip + 1, env, task, tcache)
        elif op == ENU:
            src = sorted(self._operand_set(ins.operands[0], env))
            if ip == self._second_enu_idx and task.c2_slice is not None:
                b, e = task.c2_slice
                src = src[b:e]
            for v in src:
                self.counters.enu += 1
                env[ins.target] = v
                self._exec(ip + 1, env, task, tcache)
            env.pop(ins.target, None)
        elif op == RES:
            self.counters.matches += 1
            if self.collect == "matches":
                self.matches.append(tuple(env[v] for v in ins.report))
            elif self.collect == "codes":
                self.codes.append({v: env[v] for v in ins.report})
            self._exec(ip + 1, env, task, tcache)
        else:
            raise ValueError(f"ref engine cannot execute {op}")


# --------------------------------------------------------------------------
# Brute-force oracle (independent of the plan machinery)
# --------------------------------------------------------------------------


def enumerate_matches_brute(pattern: Pattern, graph: Graph,
                            constraints: Sequence[Tuple[int, int]] = ()
                            ) -> List[Tuple[int, ...]]:
    """All injective order-respecting matches of P in G by naive backtracking."""
    cons = list(constraints)
    n = pattern.n
    out: List[Tuple[int, ...]] = []
    assign: List[int] = [-1] * n
    used: Set[int] = set()

    adjacency = [set(int(w) for w in graph.adj[v]) for v in range(graph.n)]

    def ok(u: int, v: int) -> bool:
        for w in pattern.adj[u]:
            if assign[w] >= 0 and assign[w] not in adjacency[v]:
                return False
        for a, b in cons:
            if a == u and assign[b] >= 0 and not v < assign[b]:
                return False
            if b == u and assign[a] >= 0 and not assign[a] < v:
                return False
        return True

    def rec(u: int) -> None:
        if u == n:
            out.append(tuple(assign))
            return
        for v in range(graph.n):
            if v in used or not ok(u, v):
                continue
            assign[u] = v
            used.add(v)
            rec(u + 1)
            assign[u] = -1
            used.discard(v)

    rec(0)
    return out


def count_isomorphic_subgraphs(pattern: Pattern, graph: Graph) -> int:
    """#subgraphs of G isomorphic to P = #matches / |Aut(P)|."""
    total = len(enumerate_matches_brute(pattern, graph))
    n_aut = len(pattern.automorphisms)
    assert total % n_aut == 0
    return total // n_aut
