"""S-BENU: continuous subgraph enumeration on dynamic directed graphs (§5).

The continuous problem is reduced to ordinary subgraph enumeration of the
*incremental pattern graphs* ΔP_i (Definition 5): the i-th incremental
pattern fixes edge i of P as a **delta** edge, edges before i as **either**
and edges after i as **unaltered**. Theorems 1-5 guarantee that enumerating
incremental matches of every ΔP_i in the two snapshots G'_t / G'_{t-1}
yields exactly ΔR_t^+ / ΔR_t^- with no duplicates and no omissions.

This module provides

* :class:`IncrementalPattern` — ΔP_i with its edge-type mapping τ_i,
* :func:`generate_sbenu_plan` / :func:`generate_best_sbenu_plans` — the
  incremental execution-plan compiler (§5.3-§5.4): pinned (u_si, u_ti)
  prefix, typed/directed DBQ, Delta-ENU, INS back-edge tests, useless-DBQ
  removal, CSE + reordering (no triangle cache, per the paper),
* :class:`SBenuRefEngine` — the per-task interpreter over a
  :class:`~repro.graph.dynamic.SnapshotStore`,
* :func:`run_timestep` — Algorithm 4's continuous-enumeration phase,
* :func:`snapshot_diff_oracle` — an independent brute-force oracle.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from ..graph.dynamic import SnapshotStore, Update
from ..graph.storage import DiGraph
from .estimate import DEFAULT_STATS, GraphStats
from .instructions import (DBQ, DENU, ENU, INI, INS, INT, RES, Instr, Plan,
                           Var, substitute)
from .pattern import Pattern
from .plangen import (common_subexpression_elimination, reorder_instructions,
                      search_matching_orders, uni_operand_elimination,
                      estimate_computation_cost)
from .symmetry import symmetry_breaking_constraints

# edge types
EITHER, DELTA, UNALTERED = "either", "delta", "unaltered"
_TYPE_LETTER = {EITHER: "E", DELTA: "D", UNALTERED: "U"}


# --------------------------------------------------------------------------
# Incremental pattern graphs (Definition 5)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class IncrementalPattern:
    """ΔP_i: the pattern P with edge-type mapping τ_i.

    ``delta_edge`` is the paper's 1-based edge index i; ``pattern.edges[i-1]``
    is the delta edge.
    """

    pattern: Pattern
    delta_edge: int  # 1-based

    def __post_init__(self):
        if not self.pattern.directed:
            raise ValueError("S-BENU patterns are directed")
        if not (1 <= self.delta_edge <= self.pattern.m):
            raise ValueError(f"delta edge {self.delta_edge} out of range")

    def tau(self, k: int) -> str:
        """Type of the k-th (1-based) edge of P under τ_i."""
        if k < self.delta_edge:
            return EITHER
        if k == self.delta_edge:
            return DELTA
        return UNALTERED

    def edge_type(self, e: Tuple[int, int]) -> str:
        k = self.pattern.edges.index(e) + 1
        return self.tau(k)

    @property
    def delta_src(self) -> int:
        return self.pattern.edges[self.delta_edge - 1][0]

    @property
    def delta_dst(self) -> int:
        return self.pattern.edges[self.delta_edge - 1][1]

    # -------------------------------------------------- dual condition (§5.4)
    def neighborhood_contained(self, x: int, y: int) -> bool:
        """True iff the typed neighborhood of u_x is contained in u_y's."""
        P = self.pattern
        es = set(P.edges)
        for (a, b) in P.edges:
            if a == x and b != y:          # e = (u_x, u_z)
                if (y, b) not in es or self.edge_type((a, b)) != \
                        self.edge_type((y, b)):
                    return False
            if b == x and a != y:          # e = (u_z, u_x)
                if (a, y) not in es or self.edge_type((a, b)) != \
                        self.edge_type((a, y)):
                    return False
        return True

    def syntactic_equivalent(self, x: int, y: int) -> bool:
        return (self.neighborhood_contained(x, y)
                and self.neighborhood_contained(y, x))

    def se_classes(self) -> List[List[int]]:
        n = self.pattern.n
        cls: List[List[int]] = []
        assigned = [False] * n
        for a in range(n):
            if assigned[a]:
                continue
            group = [a]
            assigned[a] = True
            for b in range(a + 1, n):
                if not assigned[b] and self.syntactic_equivalent(a, b):
                    group.append(b)
                    assigned[b] = True
            cls.append(group)
        return cls


def incremental_patterns(pattern: Pattern) -> List[IncrementalPattern]:
    return [IncrementalPattern(pattern, i) for i in range(1, pattern.m + 1)]


# --------------------------------------------------------------------------
# Incremental execution plan generation (§5.3.2)
# --------------------------------------------------------------------------


def _adj_var(type_: str, direction: str, vertex: int) -> Var:
    """('AEO', 3) style S-BENU adjacency variable."""
    return ("A" + _TYPE_LETTER[type_] + ("I" if direction == "in" else "O"),
            vertex)


def generate_sbenu_raw_plan(dp: IncrementalPattern,
                            order: Sequence[int],
                            constraints: Optional[Sequence[Tuple[int, int]]]
                            = None) -> Plan:
    """Raw incremental plan for ΔP_i bound to matching order ``order``.

    ``order`` must start with (u_si, u_ti) — the endpoints of the delta edge.
    """
    P = dp.pattern
    s, t = dp.delta_src, dp.delta_dst
    if tuple(order[:2]) != (s, t):
        raise ValueError(f"order must start with delta endpoints ({s},{t})")
    if sorted(order) != list(range(P.n)):
        raise ValueError(f"order {order} is not a permutation of V(P)")
    if constraints is None:
        constraints = symmetry_breaking_constraints(P)
    cons = set(map(tuple, constraints))
    pos = {u: i for i, u in enumerate(order)}
    es = set(P.edges)

    instrs: List[Instr] = []

    def filters_for(u: int, upto: int) -> Tuple[Tuple[str, Var], ...]:
        fcs: List[Tuple[str, Var]] = []
        for j in order[:upto]:
            if (j, u) in cons:
                fcs.append((">", ("f", j)))
            elif (u, j) in cons:
                fcs.append(("<", ("f", j)))
            elif j not in P.adj[u]:
                fcs.append(("!=", ("f", j)))
        return tuple(fcs)

    def dbqs_for(u: int) -> List[Instr]:
        """The {either,unaltered} x {in,out} adjacency fetches for u."""
        out = []
        for ty in (EITHER, UNALTERED):
            for di in ("in", "out"):
                out.append(Instr(DBQ, _adj_var(ty, di, u),
                                 operands=(("f", u),),
                                 adj_type=ty, adj_dir=di, adj_op="op"))
        return out

    # ---- bootstrap: the delta edge (Alg. 4 lines 12-16)
    instrs.append(Instr(INI, ("f", s)))
    instrs.append(Instr(DBQ, _adj_var(DELTA, "out", s), operands=(("f", s),),
                        adj_type=DELTA, adj_dir="out", adj_op="*"))
    instrs.append(Instr(INT, ("C", t), operands=(_adj_var(DELTA, "out", s),),
                        filters=filters_for(t, 1)))
    instrs.append(Instr(DENU, ("f", t), operands=(("C", t),)))
    instrs.extend(dbqs_for(s))
    instrs.extend(dbqs_for(t))
    # back edge (u_t, u_s): existence test against f_t's typed out-adjacency
    if (t, s) in es:
        ty = dp.edge_type((t, s))
        instrs.append(Instr(INS, None,
                            operands=(("f", s), _adj_var(ty, "out", t))))

    # ---- remaining vertices
    for i in range(2, P.n):
        u = order[i]
        ops: List[Var] = []
        for x in sorted((x for x in P.adj_in[u] if pos[x] < i),
                        key=lambda x: pos[x]):
            ops.append(_adj_var(dp.edge_type((x, u)), "out", x))
        for x in sorted((x for x in P.adj_out[u] if pos[x] < i),
                        key=lambda x: pos[x]):
            ops.append(_adj_var(dp.edge_type((u, x)), "in", x))
        if not ops:
            raise ValueError("pattern must be connected under the order")
        instrs.append(Instr(INT, ("T", u), operands=tuple(ops)))
        instrs.append(Instr(INT, ("C", u), operands=(("T", u),),
                            filters=filters_for(u, i)))
        instrs.append(Instr(ENU, ("f", u), operands=(("C", u),)))
        instrs.extend(dbqs_for(u))

    instrs.append(Instr(RES, None,
                        report=tuple(("f", u) for u in range(P.n))))

    plan = Plan(pattern_name=P.name, n=P.n, matching_order=tuple(order),
                instrs=instrs, constraints=tuple(sorted(cons)),
                delta_edge=dp.delta_edge)
    remove_useless_dbqs(plan)
    uni_operand_elimination(plan)
    return plan


def remove_useless_dbqs(plan: Plan) -> int:
    """Drop DBQ instructions whose targets no other instruction reads."""
    removed = 0
    changed = True
    while changed:
        changed = False
        used: Set[Var] = set()
        for ins in plan.instrs:
            used.update(ins.uses())
        for idx, ins in enumerate(plan.instrs):
            if ins.op == DBQ and ins.target not in used:
                del plan.instrs[idx]
                removed += 1
                changed = True
                break
    return removed


def generate_sbenu_plan(dp: IncrementalPattern,
                        order: Sequence[int],
                        use_cse: bool = True,
                        use_reorder: bool = True) -> Plan:
    """Optimized incremental plan (CSE + reordering; no TRC — §5.4)."""
    plan = generate_sbenu_raw_plan(dp, order)
    if use_cse:
        common_subexpression_elimination(plan)
    if use_reorder:
        reorder_instructions(plan)
    return plan


def generate_best_sbenu_plans(pattern: Pattern,
                              stats: GraphStats = DEFAULT_STATS,
                              use_cse: bool = True,
                              use_reorder: bool = True) -> List[Plan]:
    """Best incremental execution plan per ΔP_i (modified Alg. 3, §5.4)."""
    plans: List[Plan] = []
    for dp in incremental_patterns(pattern):
        prefix = (dp.delta_src, dp.delta_dst)
        sr = search_matching_orders(pattern, stats, fixed_prefix=prefix,
                                    delta_edge=dp.delta_edge,
                                    se_classes=dp.se_classes())
        best: Optional[Plan] = None
        best_cost = float("inf")
        for order in sr.candidates:
            plan = generate_sbenu_plan(dp, order, use_cse=use_cse,
                                       use_reorder=use_reorder)
            cost = estimate_computation_cost(pattern, plan, stats)
            if cost < best_cost:
                best_cost = cost
                best = plan
        assert best is not None, f"no candidate order for dP_{dp.delta_edge}"
        plans.append(best)
    return plans


# --------------------------------------------------------------------------
# Reference engine (Algorithm 4, enumeration sub-phase)
# --------------------------------------------------------------------------


class FlaggedSet(list):
    """A delta adjacency set: list of ``(op, vertex)`` with op in {'+','-'}."""


@dataclass
class SBenuCounters:
    dbq: int = 0
    int_: int = 0
    ins: int = 0
    enu: int = 0
    matches_plus: int = 0
    matches_minus: int = 0
    per_task_work: List[int] = None  # type: ignore

    def __post_init__(self):
        if self.per_task_work is None:
            self.per_task_work = []


class SBenuRefEngine:
    """Interprets the m incremental plans over a SnapshotStore at step t."""

    def __init__(self, plans: Sequence[Plan], pattern: Pattern,
                 store: SnapshotStore, collect: str = "matches",
                 cache_capacity: Optional[int] = None):
        self.plans = list(plans)
        self.pattern = pattern
        self.store = store
        self.collect = collect
        self.counters = SBenuCounters()
        self.delta_plus: List[Tuple[int, ...]] = []
        self.delta_minus: List[Tuple[int, ...]] = []
        # local DB cache (paper §6.1/§6.2 cache-format): keyed by vertex,
        # value = the full quad; hits avoid "remote" store queries.
        self.cache_capacity = cache_capacity
        self._cache: Dict[int, Dict[Tuple[str, str, str], frozenset]] = {}
        self.remote_queries = 0
        self.total_queries = 0

    # ------------------------------------------------------------------ run
    def run_timestep(self, theta: Optional[int] = None) -> None:
        """Enumerate ΔR_t^± for the store's current (begun) step."""
        self.run_starts(self.store.start_vertices(), theta=theta)

    def run_starts(self, starts, theta: Optional[int] = None) -> None:
        """Run the local search tasks for ``starts``; heavy tasks θ-split
        on their delta adjacency list. The single task-split rule shared
        with the unified Executor's sbenu backend."""
        for start in starts:
            start = int(start)
            delta_out = self.store.delta_adj_out(start)
            if theta is not None and len(delta_out) > theta:
                n_sub = -(-len(delta_out) // theta)
                for si in range(n_sub):
                    sl = delta_out[si * theta:(si + 1) * theta]
                    self._run_task(start, sl)
            else:
                self._run_task(start, delta_out)

    def _run_task(self, start: int,
                  delta_out: List[Tuple[str, int]]) -> None:
        work0 = self.counters.int_ + self.counters.enu
        for plan in self.plans:
            env: Dict[Var, object] = {"__delta_out__": delta_out}
            self._exec(plan, 0, env, start, None)
        self.counters.per_task_work.append(
            self.counters.int_ + self.counters.enu - work0)

    # -------------------------------------------------------------- adjacency
    def _get_adj(self, v: int, ty: str, di: str, op: str) -> object:
        self.total_queries += 1
        if self.cache_capacity is not None:
            self.total_queries -= 1  # counted below per-cache semantics
            return self._get_adj_cached(v, ty, di, op)
        return self.store.get_adj(v, ty, di, op)

    def _get_adj_cached(self, v: int, ty: str, di: str, op: str) -> object:
        self.total_queries += 1
        quad = self._cache.get(v)
        if quad is None:
            self.remote_queries += 1
            quad = {}
            for ty2 in (EITHER, DELTA, UNALTERED):
                for di2 in ("in", "out"):
                    for op2 in ("+", "-"):
                        quad[(ty2, di2, op2)] = self.store.get_adj(
                            v, ty2, di2, op2)
            if self.cache_capacity > 0:
                self._cache[v] = quad
                if len(self._cache) > self.cache_capacity:
                    self._cache.pop(next(iter(self._cache)))
        return quad[(ty, di, op)]

    # ------------------------------------------------------------- interpret
    def _apply_filters(self, values, filters, env):
        flagged = isinstance(values, FlaggedSet)
        out = []
        for x in values:
            w = x[1] if flagged else x   # flagged delta entries are (op, w)
            ok = True
            for op, var in filters:
                fv = env[var]
                if op == "<" and not w < fv:
                    ok = False
                elif op == ">" and not w > fv:
                    ok = False
                elif op == "!=" and w == fv:
                    ok = False
                if not ok:
                    break
            if ok:
                out.append(x)
        return FlaggedSet(out) if flagged else out

    def _exec(self, plan: Plan, ip: int, env: Dict[Var, object],
              start: int, op: Optional[str]) -> None:
        if ip >= len(plan.instrs):
            return
        ins = plan.instrs[ip]
        kind = ins.op
        if kind == INI:
            env[ins.target] = start
            self._exec(plan, ip + 1, env, start, op)
        elif kind == DBQ:
            v = env[ins.operands[0]]
            self.counters.dbq += 1
            if ins.adj_type == DELTA and ins.adj_op == "*":
                if v == start and env.get("__delta_out__") is not None \
                        and ins.adj_dir == "out":
                    env[ins.target] = FlaggedSet(env["__delta_out__"])
                else:  # pragma: no cover - plans always query the start here
                    plus = self._get_adj(v, DELTA, ins.adj_dir, "+")
                    minus = self._get_adj(v, DELTA, ins.adj_dir, "-")
                    env[ins.target] = FlaggedSet(sorted(
                        [("+", w) for w in plus] + [("-", w) for w in minus],
                        key=lambda x: x[1]))
            else:
                eff_op = op if ins.adj_op == "op" else ins.adj_op
                assert eff_op in ("+", "-"), "op not yet bound"
                env[ins.target] = self._get_adj(
                    v, ins.adj_type, ins.adj_dir, eff_op)
            self._exec(plan, ip + 1, env, start, op)
        elif kind == INT:
            self.counters.int_ += 1
            sets = [env[v] for v in ins.operands]
            if any(isinstance(s, FlaggedSet) for s in sets):
                # delta (flagged) set intersected with plain sets
                flagged = [s for s in sets if isinstance(s, FlaggedSet)]
                plain = [frozenset(s) for s in sets
                         if not isinstance(s, FlaggedSet)]
                assert len(flagged) == 1
                acc = FlaggedSet(x for x in flagged[0]
                                 if all(x[1] in p for p in plain))
            else:
                fs = sorted((frozenset(s) for s in sets), key=len)
                acc = fs[0]
                for s in fs[1:]:
                    acc = acc & s
                acc = sorted(acc)
            acc = self._apply_filters(acc, ins.filters, env)
            env[ins.target] = acc
            self._exec(plan, ip + 1, env, start, op)
        elif kind == INS:
            self.counters.ins += 1
            fv = env[ins.operands[0]]
            if fv in env[ins.operands[1]]:
                self._exec(plan, ip + 1, env, start, op)
            # else: backtrack
        elif kind == DENU:
            src = env[ins.operands[0]]
            for entry in src:
                eop, w = entry
                self.counters.enu += 1
                env[ins.target] = w
                self._exec(plan, ip + 1, env, start, eop)
            env.pop(ins.target, None)
        elif kind == ENU:
            src = env[ins.operands[0]]
            for w in sorted(src):
                self.counters.enu += 1
                env[ins.target] = w
                self._exec(plan, ip + 1, env, start, op)
            env.pop(ins.target, None)
        elif kind == RES:
            match = tuple(env[v] for v in ins.report)
            if op == "+":
                self.counters.matches_plus += 1
                self.delta_plus.append(match)
            else:
                self.counters.matches_minus += 1
                self.delta_minus.append(match)
            self._exec(plan, ip + 1, env, start, op)
        else:  # pragma: no cover
            raise ValueError(f"S-BENU engine cannot execute {kind}")


def run_timestep(pattern: Pattern, plans: Sequence[Plan],
                 store: SnapshotStore, batch: Sequence[Update],
                 theta: Optional[int] = None,
                 cache_capacity: Optional[int] = None,
                 chunk: int = 64, engine: str = "ref",
                 collect: str = "matches", backend=None, **backend_kwargs
                 ) -> Tuple[Set[Tuple[int, ...]], Set[Tuple[int, ...]],
                            SBenuCounters]:
    """One full Alg. 4 iteration: pre-process, enumerate, post-process.

    The enumeration sub-phase routes through the unified Executor API
    (core/executor.py). ``engine`` picks the backend: ``"ref"`` (alias
    ``"sbenu"``) interprets every task in Python; ``"sbenu-jax"`` runs the
    vectorized delta-frontier engine over the six-block device snapshot;
    ``"sbenu-dist"`` runs the shard_map SPMD variant over the mesh-sharded
    snapshot.
    Either way the shared driver chunks the touched-vertex start set and
    splits overloaded chunks (θ delta-slicing for the interpreter, adaptive
    re-chunking for the JIT engine).

    Passing a prepared ``backend`` reuses it (the JIT backend then keeps
    its compiled runners across the whole stream instead of recompiling
    every step).
    """
    from .executor import (ExecutorConfig, SBenuBackend, SBenuDistBackend,
                           SBenuJaxBackend, drive)
    store.begin_step(batch)
    if backend is None:
        if engine in ("ref", "sbenu"):
            backend = SBenuBackend(pattern, cache_capacity=cache_capacity,
                                   collect=collect, **backend_kwargs)
        elif engine == "sbenu-jax":
            backend = SBenuJaxBackend(pattern, collect=collect,
                                      **backend_kwargs)
        elif engine == "sbenu-dist":
            backend = SBenuDistBackend(pattern, collect=collect,
                                       **backend_kwargs)
        else:
            raise ValueError(f"unknown S-BENU engine {engine!r}")
    st = drive(backend, list(plans), store,
               ExecutorConfig(batch=chunk, theta=theta,
                              collect_matches=(collect == "matches")))
    store.end_step()
    return (st.extras["delta_plus"], st.extras["delta_minus"],
            st.extras["counters"])


# --------------------------------------------------------------------------
# Independent oracle: brute-force snapshot diff
# --------------------------------------------------------------------------


def enumerate_matches_digraph(pattern: Pattern, g: DiGraph,
                              constraints: Sequence[Tuple[int, int]] = ()
                              ) -> Set[Tuple[int, ...]]:
    """All order-respecting injective matches of a directed P in g."""
    n = pattern.n
    cons = list(constraints)
    out: Set[Tuple[int, ...]] = set()
    assign = [-1] * n
    used: Set[int] = set()

    def ok(u: int, v: int) -> bool:
        for w in pattern.adj_out[u]:
            if assign[w] >= 0 and assign[w] not in g.out[v]:
                return False
        for w in pattern.adj_in[u]:
            if assign[w] >= 0 and v not in g.out[assign[w]]:
                return False
        for a, b in cons:
            if a == u and assign[b] >= 0 and not v < assign[b]:
                return False
            if b == u and assign[a] >= 0 and not assign[a] < v:
                return False
        return True

    def rec(u: int) -> None:
        if u == n:
            out.add(tuple(assign))
            return
        for v in range(g.n):
            if v in used or not ok(u, v):
                continue
            assign[u] = v
            used.add(v)
            rec(u + 1)
            assign[u] = -1
            used.discard(v)

    rec(0)
    return out


def snapshot_diff_oracle(pattern: Pattern, store: SnapshotStore,
                         batch: Sequence[Update]
                         ) -> Tuple[Set[Tuple[int, ...]],
                                    Set[Tuple[int, ...]]]:
    """ΔR_t^± by brute force on materialized snapshots (test oracle).

    Must be called *before* the engine's begin_step (it materializes both
    snapshots itself and leaves the store untouched).
    """
    cons = symmetry_breaking_constraints(pattern)
    prev = store.snapshot("prev")
    cur = prev.copy()
    for op, a, b in batch:
        if op == "+":
            cur.add_edge(a, b)
        else:
            cur.remove_edge(a, b)
    r_prev = enumerate_matches_digraph(pattern, prev, cons)
    r_cur = enumerate_matches_digraph(pattern, cur, cons)
    return r_cur - r_prev, r_prev - r_cur
