"""Symmetry breaking (paper §2.2).

Implements the Grochow–Kellis technique [22]: impose a partial order ``<`` on
V(P) such that every subgraph of G isomorphic to P admits exactly one match
respecting ``f(u_i) < f(u_j)`` under the total order on V(G).

The classic construction: repeatedly pick the largest automorphism orbit,
anchor its minimum vertex ``u`` with conditions ``u < w`` for every other
orbit member ``w``, then restrict the automorphism group to the stabilizer of
``u``; stop when the group is trivial.
"""

from __future__ import annotations

from typing import List, Set, Tuple

from .pattern import Pattern

Constraint = Tuple[int, int]  # (a, b) means f(u_a) < f(u_b)


def orbits(perms: List[Tuple[int, ...]], n: int) -> List[Set[int]]:
    """Vertex orbits under a set of permutations (union-find)."""
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for p in perms:
        for v in range(n):
            a, b = find(v), find(p[v])
            if a != b:
                parent[a] = b
    groups = {}
    for v in range(n):
        groups.setdefault(find(v), set()).add(v)
    return list(groups.values())


def symmetry_breaking_constraints(pattern: Pattern) -> List[Constraint]:
    """Partial-order constraints ``(a, b)`` meaning ``f(u_a) < f(u_b)``."""
    perms = list(pattern.automorphisms)
    constraints: List[Constraint] = []
    while len(perms) > 1:
        obs = [o for o in orbits(perms, pattern.n) if len(o) > 1]
        if not obs:  # non-trivial perms but trivial orbits cannot happen
            break
        # largest orbit; ties -> containing the smallest vertex id
        orbit = max(obs, key=lambda o: (len(o), -min(o)))
        anchor = min(orbit)
        for w in sorted(orbit):
            if w != anchor:
                constraints.append((anchor, w))
        perms = [p for p in perms if p[anchor] == anchor]
    return constraints


def check_unique_representative(pattern: Pattern,
                                constraints: List[Constraint]) -> bool:
    """Verify the defining property: for every automorphism image of the
    identity labeling, exactly one permutation of each automorphism class of
    labelings satisfies the constraints.

    Concretely: among ``{perm : perm in Aut(P)}`` applied to any injective
    labeling, exactly one ordering survives. We check on the canonical
    labeling ``u_i -> i``: matches of P onto itself are automorphisms, and
    exactly one automorphism image must satisfy all constraints.
    """
    ok = 0
    for p in pattern.automorphisms:
        # labeling v -> p[v]; constraint (a, b): p[a] < p[b]
        if all(p[a] < p[b] for a, b in constraints):
            ok += 1
    return ok == 1
