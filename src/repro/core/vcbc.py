"""VCBC (vertex-cover based compression) support (paper §4.2.4).

Given a plan whose matching order's first ``k`` vertices form a vertex cover
V_c of P (and first k-1 do not), the matches of the first k vertices are the
*helves*; each non-core vertex u_j contributes its *conditional image set*
C_j. The plan is modified to delete non-core ENU instructions and report
``(helve, image sets)`` compressed codes directly.

``expand_code`` reconstructs exact match tuples from a code — used to verify
compressed counting against uncompressed enumeration. Expansion enforces the
residual constraints the plan dropped: injectivity and symmetry-order
constraints *between non-core vertices* (non-core vertices are pairwise
non-adjacent because V_c is a vertex cover, so the plan never checked these).
"""

from __future__ import annotations

import itertools
from dataclasses import replace
from typing import Dict, Iterable, List, Sequence, Tuple

from .instructions import DBQ, ENU, INT, RES, Instr, Plan, Var
from .pattern import Pattern


def compress_plan(plan: Plan, pattern: Pattern, core_k: int) -> None:
    """Modify ``plan`` in place to emit VCBC-compressed codes."""
    order = plan.matching_order
    core = set(order[:core_k])
    noncore = [u for u in order[core_k:]]
    noncore_f: set = {("f", u) for u in noncore}

    out: List[Instr] = []
    for ins in plan.instrs:
        if ins.op == ENU and ins.target in noncore_f:
            continue                       # delete non-core enumeration
        if ins.op == DBQ and ins.operands[0] in noncore_f:
            continue  # cannot happen for a true cover; defensive
        if ins.filters:
            flt = tuple((op, v) for op, v in ins.filters
                        if v not in noncore_f)
            ins = replace(ins, filters=flt)
        if ins.op == RES:
            rep = tuple(("C", v[1]) if v in noncore_f else v
                        for v in ins.report)
            ins = replace(ins, report=rep)
        out.append(ins)
    plan.instrs[:] = out
    plan.vcbc = True
    plan.core_k = core_k


def residual_constraints(plan: Plan, pattern: Pattern
                         ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
    """(order_constraints, injective_pairs) among non-core vertices."""
    core = set(plan.matching_order[:plan.core_k])
    noncore = [u for u in plan.matching_order[plan.core_k:]]
    order_c = [(a, b) for a, b in plan.constraints
               if a not in core and b not in core]
    inj = [(a, b) for i, a in enumerate(noncore) for b in noncore[i + 1:]]
    return order_c, inj


def expand_code(plan: Plan, pattern: Pattern,
                code: Dict[Var, object]) -> List[Tuple[int, ...]]:
    """Expand one compressed code ``{('f',i): v, ('C',j): iterable}`` into the
    exact list of match tuples (f_1..f_n)."""
    order_c, inj = residual_constraints(plan, pattern)
    noncore = [u for u in plan.matching_order[plan.core_k:]]
    fixed = {u: code[("f", u)] for u in plan.matching_order[:plan.core_k]}
    image_sets = [sorted(code[("C", u)]) for u in noncore]
    out: List[Tuple[int, ...]] = []
    for combo in itertools.product(*image_sets):
        assign = dict(fixed)
        ok = True
        for u, v in zip(noncore, combo):
            assign[u] = v
        for a, b in inj:
            if assign[a] == assign[b]:
                ok = False
                break
        if ok:
            for a, b in order_c:
                if not assign[a] < assign[b]:
                    ok = False
                    break
        if ok:
            out.append(tuple(assign[u] for u in range(pattern.n)))
    return out


def count_code(plan: Plan, pattern: Pattern, code: Dict[Var, object]) -> int:
    """Exact number of matches a compressed code expands to.

    With <= 3 non-core vertices (all the paper's patterns) inclusion-
    exclusion over equal-value collisions is cheap; we expand for full
    generality since image sets are small.
    """
    return len(expand_code(plan, pattern, code))
