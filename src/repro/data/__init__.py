"""data package."""
