"""Deterministic synthetic data pipelines (sharded, restart-reproducible).

Every batch is a pure function of (stream seed, step), so a restarted job
regenerates the exact stream from its checkpoint step — no data-loader
state needs checkpointing. In a multi-host deployment each process slices
``[proc_index * per_proc : (proc_index+1) * per_proc]`` of the global batch
(the ``process_slice`` helper), keeping global batch identity.

The LM stream is a mixture of Zipf-distributed tokens with short-range
induced structure (copy motifs), so a few hundred steps of training show a
real loss decrease (used by examples/train_lm.py and the restart tests).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..graph.batch import (GraphBatch, NeighborSampler, synthetic_full_graph,
                           synthetic_mesh, synthetic_molecules)
from ..graph.storage import Graph


def process_slice(batch: Dict[str, np.ndarray], proc: int, n_procs: int
                  ) -> Dict[str, np.ndarray]:
    out = {}
    for k, v in batch.items():
        b = v.shape[0]
        per = b // n_procs
        out[k] = v[proc * per:(proc + 1) * per]
    return out


# --------------------------------------------------------------------------
# LM token stream
# --------------------------------------------------------------------------


@dataclass
class LMStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b, t = self.global_batch, self.seq_len
        # Zipf-ish marginal + copy motif: second half repeats the first
        # (compressible structure => CE decreases quickly)
        half = t // 2
        x = rng.zipf(self.zipf_a, size=(b, t)).astype(np.int64)
        x = np.minimum(x, self.vocab - 1)
        x[:, half:half * 2] = x[:, :half]
        tokens = x.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
        return {"tokens": tokens, "labels": labels}


# --------------------------------------------------------------------------
# RecSys stream (BST)
# --------------------------------------------------------------------------


@dataclass
class RecsysStream:
    n_items: int
    n_user_feats: int
    seq_len: int
    user_feat_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b = self.global_batch
        hist = rng.integers(1, self.n_items,
                            size=(b, self.seq_len)).astype(np.int32)
        # positive targets correlate with history (same id bucket)
        pos = (hist[:, -1] + rng.integers(0, 16, size=b)) % self.n_items
        neg = rng.integers(1, self.n_items, size=b)
        label = rng.integers(0, 2, size=b).astype(np.float32)
        target = np.where(label > 0.5, pos, neg).astype(np.int32)
        uf = rng.integers(0, self.n_user_feats,
                          size=(b, self.user_feat_len)).astype(np.int32)
        uf[:, self.user_feat_len // 2:] = 0     # ragged bags via pad id 0
        return {"hist": hist, "target": target, "user_feats": uf,
                "label": label}


# --------------------------------------------------------------------------
# GNN streams
# --------------------------------------------------------------------------


@dataclass
class FullGraphData:
    """Static full-batch dataset: the same batch each step."""

    batch: GraphBatch

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        return self.batch.as_arrays()


@dataclass
class MinibatchGraphStream:
    """Fan-out sampled blocks from a big host graph (minibatch_lg cell)."""

    graph: Graph
    feats: np.ndarray
    labels: np.ndarray
    batch_nodes: int
    fanouts: Tuple[int, ...]
    n_max: int
    e_max: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        sampler = NeighborSampler(self.graph, self.fanouts,
                                  seed=int(rng.integers(1 << 31)))
        targets = rng.choice(self.graph.n, size=self.batch_nodes,
                             replace=False)
        gb = sampler.sample_batch(targets, self.feats, self.labels,
                                  self.n_max, self.e_max)
        return gb.as_arrays()
