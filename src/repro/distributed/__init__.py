"""distributed package."""
