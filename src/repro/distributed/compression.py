"""Gradient compression for data-parallel all-reduce (distributed-opt trick).

``compressed_psum`` quantizes each gradient leaf to int8 with a per-leaf
scale before the cross-replica sum and rescales after — 4x fewer bytes on
the DP reduction wire. **Error feedback** (Seide et al. / EF-SGD) keeps the
quantization residual in a state buffer and re-injects it next step, which
restores convergence to within noise of the uncompressed baseline (validated
in tests/test_train.py by loss-curve comparison).

Usage is explicit (inside shard_map over the DP axis) because implicit-pjit
gradients hide the reduction inside XLA; the manual-DP train step in
train/loop.py opts in via ``grad_compression="int8"``.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(tree: Any, axis: str,
                    error_state: Optional[Any] = None
                    ) -> Tuple[Any, Any]:
    """int8-quantized psum over ``axis`` with error feedback.

    Returns (mean-reduced tree in f32, new error state). Must run inside
    shard_map with ``axis`` in scope. Scales are psum'd alongside (tiny).
    """
    n = jax.lax.psum(1, axis)

    def one(g, err):
        gf = g.astype(jnp.float32)
        if err is not None:
            gf = gf + err
        # agree on a COMMON scale (pmax) so the int8 payloads are summable
        local_scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        scale = jax.lax.pmax(local_scale, axis)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_err = gf - deq                       # residual -> next step
        # all-reduce int8 payload (summed in int32 to avoid overflow)
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        return summed.astype(jnp.float32) * scale / n, new_err

    if error_state is None:
        error_state = jax.tree.map(lambda _: None, tree,
                                   is_leaf=lambda x: x is None)
        flat_err = [None] * len(jax.tree.leaves(tree))
    else:
        flat_err = jax.tree.leaves(error_state)
    flat_g, treedef = jax.tree.flatten(tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_err)]
    reduced = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return reduced, new_err


def plain_psum_mean(tree: Any, axis: str) -> Any:
    n = jax.lax.psum(1, axis)
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis) / n, tree)
