"""DeviceRowCache: bounded device cache over a host row store (paper §6).

The paper's workers pull adjacency rows on demand from a distributed KV
store; a local LRU cache absorbs repeated fetches so communication scales
with *distinct cold rows*, not partial matches. This module is that cache
for the vectorized engines, with host RAM playing the remote store and
HBM playing the local cache:

* a **pinned hot set**: the top-``hot`` ids by degree live on device
  permanently. Vertices are relabeled ascending by degree at load time
  (``graph/storage.py``), so the hot set is exactly ids ``>= n - hot`` —
  the same convention as ``DistributedRowStore``'s hot-row replication.
  Hub rows are both the most re-fetched and the skew hazard; pinning them
  removes that traffic class entirely;
* an **LRU slab** of ``capacity_rows`` rows (``int32[C, D]`` on device)
  with a host-side ``id -> slot`` map. Per lookup the id batch is deduped
  (each distinct row crosses PCIe at most once per level — the vectorized
  analogue of the paper's per-task cache), misses are gathered from the
  :class:`~repro.graph.hoststore.HostRowStore` as one dense block and
  scattered into LRU slots;
* **double-buffered async prefetch**: :meth:`prefetch` stages the next
  chunk's predicted rows via ``jax.device_put`` (an async H2D copy) while
  the current chunk's compute is in flight; the staged block is adopted
  into the slab at the next lookup with a device-to-device scatter. At
  most two staged blocks exist at a time (the two buffers).

Correctness never depends on capacity: a lookup's miss block is consumed
directly (three gathers + two selects), so even ``capacity_rows=0``
serves exact rows — it just re-fetches every level.

Counters follow Fig. 10's axes: queries (rows requested), cold rows
(host->device fetches), bytes moved (demand + prefetch), per DBQ level.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..graph.hoststore import HostRowStore


@dataclass
class CacheStats:
    """Fetch-path accounting. Units: rows are padded adjacency rows
    (``d * 4`` bytes each); levels are DBQ indices within the plan."""

    queries: int = 0          # non-sentinel rows requested (pre-dedup)
    unique_queries: int = 0   # distinct rows requested per lookup, summed
    cold_rows: int = 0        # rows fetched host->device on demand
    prefetch_rows: int = 0    # rows staged ahead by prefetch()
    prefetch_used: int = 0    # staged rows later served from the slab
    hot_hits: int = 0         # rows served from the pinned hot block
    evictions: int = 0
    bytes_demand: int = 0     # demand H2D traffic (cold_rows * row bytes)
    bytes_prefetch: int = 0   # prefetch H2D traffic
    lookups: int = 0
    per_level: Dict[int, List[int]] = field(default_factory=dict)
    # per_level[lvl] = [queries, cold_rows, bytes]

    @property
    def bytes_moved(self) -> int:
        """Total H2D bytes (demand + prefetch)."""
        return self.bytes_demand + self.bytes_prefetch

    @property
    def hit_rate(self) -> float:
        """1 - cold/queries: fraction of requested rows served without a
        host fetch (hot pins, slab hits, within-batch dedup, prefetch)."""
        if self.queries == 0:
            return 0.0
        return 1.0 - self.cold_rows / self.queries

    def level_note(self, lvl: int, queries: int, cold: int,
                   nbytes: int) -> None:
        acc = self.per_level.setdefault(lvl, [0, 0, 0])
        acc[0] += queries
        acc[1] += cold
        acc[2] += nbytes

    def as_dict(self) -> Dict[str, object]:
        return dict(queries=self.queries, unique_queries=self.unique_queries,
                    cold_rows=self.cold_rows,
                    prefetch_rows=self.prefetch_rows,
                    prefetch_used=self.prefetch_used,
                    hot_hits=self.hot_hits, evictions=self.evictions,
                    bytes_moved=self.bytes_moved,
                    bytes_demand=self.bytes_demand,
                    bytes_prefetch=self.bytes_prefetch,
                    hit_rate=self.hit_rate, lookups=self.lookups,
                    per_level={k: list(v)
                               for k, v in sorted(self.per_level.items())})


class DeviceRowCache:
    """Bounded device residency over a :class:`HostRowStore`.

    Device memory held (worst case, all static):
    ``(capacity_rows + 2 * stage_rows + hot + 1) * d * 4`` bytes — the
    LRU slab, the two prefetch staging buffers, the pinned hot block and
    the sentinel row — independent of graph size.
    ``stage_rows`` bounds one staging buffer (default
    ``capacity_rows // 4``, so staging adds at most half a slab).
    """

    def __init__(self, store: HostRowStore, capacity_rows: int,
                 hot: int = 0, stage_rows: Optional[int] = None):
        import jax
        import jax.numpy as jnp
        self._jax, self._jnp = jax, jnp
        self.store = store
        self.n = store.n
        self.d = store.d
        self.capacity_rows = max(int(capacity_rows), 0)
        self.stage_rows = (self.capacity_rows // 4 if stage_rows is None
                           else max(int(stage_rows), 0))
        self.hot = min(max(int(hot), 0), store.n)
        self.hot_lo = store.n - self.hot   # ids >= hot_lo are pinned
        # pinned block rows are ids [hot_lo, n] — the top-degree set plus
        # the sentinel row, served without touching the slab
        self.hot_rows = jnp.asarray(
            store.gather(np.arange(self.hot_lo, store.n + 1)))
        self.slab = jnp.full((max(self.capacity_rows, 1), self.d),
                             store.n, jnp.int32)
        self._slot_of: "OrderedDict[int, int]" = OrderedDict()  # LRU order
        self._free: List[int] = list(range(self.capacity_rows))
        # staging buffers: (ids, device block, id -> block row) — rows
        # not yet consumed by a lookup
        self._staged: List[Tuple[np.ndarray, object, Dict[int, int]]] = []
        self._staged_ids: set = set()
        self._from_prefetch: set = set()   # slab ids that arrived staged
        self.stats = CacheStats()

    # ----------------------------------------------------------- residency
    @property
    def device_rows(self) -> int:
        """Worst-case rows held on device (slab + both staging buffers +
        pinned hot + sentinel)."""
        return self.capacity_rows + 2 * self.stage_rows + self.hot + 1

    @property
    def device_bytes(self) -> int:
        return self.device_rows * self.d * 4

    # ------------------------------------------------------------ prefetch
    def prefetch(self, ids: np.ndarray) -> None:
        """Stage rows for a *future* lookup: async ``device_put`` of the
        predicted rows that are not already resident. Call right before
        dispatching the current chunk's compute — the H2D copy overlaps
        it. Staged rows are served straight from their staging buffer
        (and promoted into the slab) the first time a lookup requests
        them — they never compete for slab slots before being read, so a
        small slab churned by deep levels cannot evict a prefetch before
        it pays off. At most two buffers are in flight (double buffering
        — a third folds the oldest into the slab).
        """
        if self.capacity_rows == 0 or self.stage_rows == 0:
            return
        ids = np.unique(np.clip(np.asarray(ids, np.int64).reshape(-1),
                                0, self.n))
        want = np.array([v for v in ids
                         if v < self.hot_lo and int(v) not in self._slot_of
                         and int(v) not in self._staged_ids], np.int64)
        if want.size == 0:
            return
        # one staging buffer's budget — staged blocks are live device
        # memory and are counted in device_rows
        want = want[:self.stage_rows]
        block_np = self.store.gather(want)
        block = self._jax.device_put(block_np)     # async H2D
        self._staged.append(
            (want, block, {int(v): i for i, v in enumerate(want)}))
        self._staged_ids.update(int(v) for v in want)
        self.stats.prefetch_rows += int(want.size)
        self.stats.bytes_prefetch += int(block_np.nbytes)
        if len(self._staged) > 2:                  # keep two buffers live
            self._adopt_one()

    def _adopt_one(self) -> None:
        """Fold the oldest staging buffer's unread rows into the slab."""
        ids, block, pos = self._staged.pop(0)
        live = np.array([v for v in ids if int(v) in pos], np.int64)
        keep_ids, keep_pos = self._alloc_slots(live)
        if keep_ids.size:
            slots = np.array([self._slot_of[int(v)] for v in keep_ids],
                             np.int32)
            src = np.array([pos[int(v)] for v in keep_ids], np.int64)
            self.slab = self.slab.at[self._jnp.asarray(slots)].set(
                block[self._jnp.asarray(src)])
            self._from_prefetch.update(int(v) for v in keep_ids)
        # release only the rows still claimed by THIS buffer: a consumed
        # id may have been evicted and re-staged in a newer buffer
        self._staged_ids.difference_update(int(v) for v in live)

    # ---------------------------------------------------------- coherence
    def invalidate(self, ids: np.ndarray) -> None:
        """Drop every cached copy of ``ids`` — slab entries, staged rows,
        and pinned hot rows (the hot rows are re-gathered from the
        store). Call after the backing store's rows change **in place**
        (e.g. a host-mode snapshot store's ``end_step`` patches touched
        rows); without it, lookups would keep serving the pre-update
        rows.
        """
        jnp = self._jnp
        ids = np.unique(np.clip(np.asarray(ids, np.int64).reshape(-1),
                                0, self.n))
        hot_ids = []
        for v in ids:
            v = int(v)
            if v >= self.hot_lo:
                if v < self.n:
                    hot_ids.append(v)
                continue
            slot = self._slot_of.pop(v, None)
            if slot is not None:
                self._free.append(slot)
            self._from_prefetch.discard(v)
            if v in self._staged_ids:
                for _, _, pos in self._staged:
                    pos.pop(v, None)
                self._staged_ids.discard(v)
        self._staged = [t for t in self._staged if t[2]]
        if hot_ids:
            idx = np.asarray(hot_ids, np.int64) - self.hot_lo
            self.hot_rows = self.hot_rows.at[jnp.asarray(idx)].set(
                jnp.asarray(self.store.gather(np.asarray(hot_ids))))

    # -------------------------------------------------------------- lookup
    def _alloc_slots(self, ids: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Assign LRU slots to as many of ``ids`` as fit; returns the kept
        ids and their positions within ``ids``."""
        if self.capacity_rows == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        ids = np.asarray(ids, np.int64)
        if ids.size > self.capacity_rows:
            # only the tail fits; earlier rows would be evicted unread
            ids_kept = ids[-self.capacity_rows:]
            pos_kept = np.arange(ids.size - self.capacity_rows, ids.size)
        else:
            ids_kept, pos_kept = ids, np.arange(ids.size)
        out_ids, out_pos = [], []
        for v, p in zip(ids_kept, pos_kept):
            v = int(v)
            if v in self._slot_of:         # already resident (race with
                self._slot_of.move_to_end(v)   # a staged duplicate)
                continue
            if self._free:
                slot = self._free.pop()
            else:
                evicted, slot = self._slot_of.popitem(last=False)  # LRU
                self._from_prefetch.discard(evicted)
                self.stats.evictions += 1
            self._slot_of[v] = slot
            out_ids.append(v)
            out_pos.append(int(p))
        return np.asarray(out_ids, np.int64), np.asarray(out_pos, np.int64)

    def lookup(self, ids: np.ndarray, level: int = 0):
        """Serve ``rows int32[B, d]`` (a jax array) for host ids ``ids``.

        ``level`` tags the plan's DBQ index for per-level accounting.
        Ids are clipped to ``[0, n]`` (ids ``>= n`` return the sentinel
        row, negatives clamp to row 0). Sources, in
        priority order: pinned hot block, LRU slab, staging buffers
        (prefetched rows — promoted into the slab on first use), then a
        demand host fetch of the remaining cold rows. The result is exact
        regardless of capacity; capacity only changes how many rows had
        to cross from the host.
        """
        jnp = self._jnp
        ids = np.clip(np.asarray(ids, np.int64).reshape(-1), 0, self.n)
        is_hot = ids >= self.hot_lo                 # includes sentinel
        nv = int(np.sum(ids < self.n))
        # -- unique-row resolution: classify each distinct id once
        uniq, inv = np.unique(ids, return_inverse=True)
        U = uniq.shape[0]
        hot_sel, slab_sel, miss_sel = [], [], []
        hot_src, slab_src = [], []
        stg_sel = [[] for _ in self._staged]
        stg_src = [[] for _ in self._staged]
        stg_hit_ids = []
        for u, v in enumerate(uniq):
            v = int(v)
            if v >= self.hot_lo:
                hot_sel.append(u)
                hot_src.append(v - self.hot_lo)
                continue
            slot = self._slot_of.get(v)
            if slot is not None:
                self._slot_of.move_to_end(v)        # LRU touch
                if v in self._from_prefetch:        # adopted unread, first
                    self.stats.prefetch_used += 1   # touch happens now
                    self._from_prefetch.discard(v)
                slab_sel.append(u)
                slab_src.append(slot)
                continue
            for bi in range(len(self._staged) - 1, -1, -1):
                pos = self._staged[bi][2].get(v)
                if pos is not None:
                    stg_sel[bi].append(u)
                    stg_src[bi].append(pos)
                    stg_hit_ids.append((bi, v))
                    break
            else:
                miss_sel.append(u)
        miss_u = uniq[miss_sel]
        # -- demand fetch: one dense host gather, one H2D block
        fresh = None
        if miss_u.size:
            fresh_np = self.store.gather(miss_u)
            fresh = jnp.asarray(fresh_np)
            self.stats.bytes_demand += int(fresh_np.nbytes)
        # -- assemble the unique rows on device, then un-dedup
        rows_u = jnp.full((U, self.d), self.n, jnp.int32)
        if hot_sel:
            rows_u = rows_u.at[jnp.asarray(np.asarray(hot_sel))].set(
                self.hot_rows[jnp.asarray(np.asarray(hot_src))])
        if slab_sel:
            rows_u = rows_u.at[jnp.asarray(np.asarray(slab_sel))].set(
                self.slab[jnp.asarray(np.asarray(slab_src))])
        for bi, (sids, block, pos) in enumerate(self._staged):
            if stg_sel[bi]:
                rows_u = rows_u.at[jnp.asarray(np.asarray(stg_sel[bi]))].set(
                    block[jnp.asarray(np.asarray(stg_src[bi]))])
        if fresh is not None:
            rows_u = rows_u.at[jnp.asarray(np.asarray(miss_sel))].set(fresh)
        out = rows_u[jnp.asarray(inv)]
        # -- promote: served staged rows + the miss block enter the slab
        promote_ids, promote_rows = [], []
        for bi, v in stg_hit_ids:
            sids, block, pos = self._staged[bi]
            promote_ids.append(v)
            promote_rows.append(block[pos.pop(v)])  # consumed: unmap it
            self._staged_ids.discard(v)
        self.stats.prefetch_used += len(stg_hit_ids)
        self._staged = [t for t in self._staged if t[2]]  # drop drained
        if promote_ids or miss_u.size:
            all_ids = np.concatenate(
                [np.asarray(promote_ids, np.int64), miss_u])
            keep_ids, keep_pos = self._alloc_slots(all_ids)
            if keep_ids.size:
                slots = np.array([self._slot_of[int(v)] for v in keep_ids],
                                 np.int32)
                source = jnp.stack(promote_rows) if promote_rows else None
                if miss_u.size:
                    source = (fresh if source is None
                              else jnp.concatenate([source, fresh], axis=0))
                self.slab = self.slab.at[jnp.asarray(slots)].set(
                    source[jnp.asarray(keep_pos)])
        # -- accounting
        st = self.stats
        st.lookups += 1
        st.queries += nv
        st.unique_queries += int(np.sum(uniq < self.n))
        st.cold_rows += int(miss_u.size)
        st.hot_hits += int(np.sum(is_hot & (ids < self.n)))
        st.level_note(level, nv, int(miss_u.size),
                      int(miss_u.size) * self.d * 4)
        return out
