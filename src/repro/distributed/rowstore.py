"""DistributedRowStore: the paper's distributed KV database, TPU-native.

The paper stores adjacency sets in HBase and lets tasks query rows on
demand. On a TPU mesh the store *is* program state: padded adjacency rows
live block-partitioned over the devices of one mesh axis, and a DBQ over a
batch of vertex ids becomes a **batched request/response all_to_all**:

    1. dedup the local id batch (``jnp.unique`` with static size) — the
       vectorized analogue of the paper's per-task DB cache: within a
       frontier level each distinct row crosses the wire at most once;
    2. route ids to their owner shard (block partition => owner = id // rps)
       through ``all_to_all`` with a static per-peer capacity R;
    3. owners gather their local rows and ``all_to_all`` the responses back.

    Communication per level ∝ (#distinct cold ids) x row bytes — never
    ∝ #partial matches. This is the paper's headline claim expressed as
    collectives.

**Hot-row replication** (beyond-paper, replaces the LRU cache's inter-task
locality): vertices are relabeled by ascending degree at load time, so ids
``>= n_hot_lo`` are exactly the highest-degree vertices. Their rows are
replicated on every device and served locally, which removes both the
traffic and the *skew* (a hub vertex would hammer its owner shard — the
distributed-DB hotspot the paper's cache also exists to absorb).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.storage import Graph


@dataclass
class RowStoreSpec:
    """Static layout of a distributed row store."""

    n: int                 # real vertices; sentinel value
    d: int                 # padded row width
    n_shards: int
    rows_per_shard: int    # ceil((n+1) / n_shards), block partition
    hot: int = 0           # top-`hot` ids replicated everywhere
    req_cap: int = 0       # per-peer request capacity R (0 = B)

    @property
    def n_padded(self) -> int:
        return self.n_shards * self.rows_per_shard


def build_row_shards(graph: Graph, n_shards: int, hot: int = 0,
                     lane: int = 128, d_max: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray, RowStoreSpec]:
    """Materialize ``(shards [S, rps, D], hot_rows [hot, D], spec)``.

    Row ``n`` (the sentinel row, all holes) is stored like any other row, so
    gathers with invalid ids round-trip safely.
    """
    rows, _ = graph.padded_adjacency(d_max=d_max, lane=lane)
    n, d = graph.n, rows.shape[1]
    rows = np.concatenate([rows, np.full((1, d), n, np.int32)], axis=0)
    rps = -(-(n + 1) // n_shards)
    pad = n_shards * rps - (n + 1)
    if pad:
        rows = np.concatenate(
            [rows, np.full((pad, d), n, np.int32)], axis=0)
    shards = rows.reshape(n_shards, rps, d)
    hot = min(hot, n)
    # relabeling is ascending-degree, so the hot set is ids [n-hot, n]
    hot_rows = rows[n - hot:n + 1] if hot > 0 else rows[n:n + 1]
    spec = RowStoreSpec(n=n, d=d, n_shards=n_shards, rows_per_shard=rps,
                        hot=hot)
    return shards, hot_rows, spec


def make_distributed_fetch(spec, axis: str, req_cap: int):
    """Build ``fetch(ids, local_shard, hot_rows) -> (rows, n_cold, drops)``
    for use *inside* shard_map over mesh axis ``axis``.

    ``req_cap`` (R) is the static per-peer request budget. ``drops`` counts
    requests beyond R (the driver treats drops > 0 like frontier overflow
    and retries with a smaller start batch / larger R).

    ``spec`` is duck-typed (``n`` / ``n_shards`` / ``rows_per_shard`` /
    ``hot``): the row width comes from ``local_shard`` at call time, so one
    fetch serves stores of any width sharing a layout — the streaming
    engine reuses it for all six snapshot blocks
    (:class:`~repro.graph.dynamic.SnapshotShardSpec`).
    """
    S = spec.n_shards
    rps = spec.rows_per_shard
    sent = spec.n
    hot_lo = spec.n - spec.hot  # ids >= hot_lo are replicated

    def fetch(ids: jax.Array, local_shard: jax.Array,
              hot_rows: jax.Array):
        B = ids.shape[0]
        is_hot = ids >= hot_lo                    # includes sentinel ids
        cold_ids = jnp.where(is_hot, sent, ids)
        # -- dedup (per-level DB-cache analogue)
        uids = jnp.unique(cold_ids, size=B, fill_value=sent)
        inv = jnp.searchsorted(uids, cold_ids).astype(jnp.int32)
        owner = jnp.clip(uids // rps, 0, S - 1).astype(jnp.int32)
        # slot of each unique id within its owner group (owners are sorted)
        first = jnp.searchsorted(owner, owner, side="left").astype(jnp.int32)
        slot = jnp.arange(B, dtype=jnp.int32) - first
        want = uids != sent
        ok = want & (slot < req_cap)
        drops = jnp.sum(want & ~ok)
        n_cold = jnp.sum(want)
        # -- build request matrix [S, R]
        reqs = jnp.full((S, req_cap), sent, jnp.int32)
        reqs = reqs.at[owner, slot].set(jnp.where(ok, uids, sent),
                                        mode="drop")
        # -- route requests to owners
        recv = jax.lax.all_to_all(reqs, axis, split_axis=0, concat_axis=0,
                                  tiled=False)          # [S, R] ids to serve
        # -- serve from the local block
        me = jax.lax.axis_index(axis)
        lid = recv - me * rps
        lval = (lid >= 0) & (lid < rps) & (recv != sent)
        lrows = local_shard[jnp.clip(lid, 0, rps - 1)]   # [S, R, D]
        lrows = jnp.where(lval[..., None], lrows, sent)
        # -- route responses back (same slots)
        resp = jax.lax.all_to_all(lrows, axis, split_axis=0, concat_axis=0,
                                  tiled=False)           # [S, R, D]
        flat = resp.reshape(S * req_cap, resp.shape[-1])
        got_u = flat[jnp.clip(owner * req_cap + slot, 0, S * req_cap - 1)]
        got_u = jnp.where(ok[:, None], got_u, sent)      # [B, D] unique rows
        out = got_u[inv]                                 # un-dedup
        # -- hot rows served locally
        hidx = jnp.clip(ids - hot_lo, 0, hot_rows.shape[0] - 1)
        out = jnp.where(is_hot[:, None], hot_rows[hidx], out)
        out = jnp.where((ids >= sent)[:, None], sent, out)
        return out, n_cold, drops

    return fetch
