"""graph package."""
