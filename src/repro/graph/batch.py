"""Padded graph batches + neighbor sampling (GNN substrate).

JAX needs static shapes, so every graph workload is normalized into a
:class:`GraphBatch`: sentinel-padded edge lists plus segment-sum message
passing (`jax.ops.segment_sum` over an edge-index -> node scatter — JAX has
no CSR SpMM; this IS the system's message-passing primitive, shared with the
BENU row substrate).

Conventions: edge endpoints == ``n_nodes`` are padding (they scatter into a
dropped extra segment); node rows beyond ``n_valid`` are zeros.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .storage import Graph


@dataclass
class GraphBatch:
    """Host-side batch; fields become the device arrays of input_specs."""

    x: np.ndarray             # [N, F] float32
    edge_src: np.ndarray      # [E] int32 (sentinel N = padding)
    edge_dst: np.ndarray      # [E] int32
    labels: np.ndarray        # [N] or [G] int32/float32
    n_nodes: int              # static row count N
    node_mask: np.ndarray     # [N] bool
    loss_mask: np.ndarray     # [N] or [G] bool (supervised nodes/graphs)
    graph_ids: Optional[np.ndarray] = None   # [N] int32 (batched graphs)
    n_graphs: int = 1
    pos: Optional[np.ndarray] = None          # [N, 3] (EGNN)
    edge_attr: Optional[np.ndarray] = None    # [E, de] (MeshGraphNet)
    targets: Optional[np.ndarray] = None      # [N, dt] regression targets

    def as_arrays(self) -> Dict[str, np.ndarray]:
        out = {"x": self.x, "edge_src": self.edge_src,
               "edge_dst": self.edge_dst, "labels": self.labels,
               "node_mask": self.node_mask, "loss_mask": self.loss_mask}
        if self.graph_ids is not None:
            out["graph_ids"] = self.graph_ids
        if self.pos is not None:
            out["pos"] = self.pos
        if self.edge_attr is not None:
            out["edge_attr"] = self.edge_attr
        if self.targets is not None:
            out["targets"] = self.targets
        return out


# --------------------------------------------------------------------------
# Synthetic full graphs (Cora-like / products-like)
# --------------------------------------------------------------------------


def synthetic_full_graph(n_nodes: int, n_edges: int, d_feat: int,
                         n_classes: int, seed: int = 0,
                         directed_double: bool = True) -> GraphBatch:
    """ER-ish graph with features correlated to labels (learnable signal)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    if directed_double:   # symmetric message passing
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    x = (centers[labels] + rng.normal(size=(n_nodes, d_feat)) * 2.0
         ).astype(np.float32)
    return GraphBatch(
        x=x, edge_src=src.astype(np.int32), edge_dst=dst.astype(np.int32),
        labels=labels, n_nodes=n_nodes,
        node_mask=np.ones(n_nodes, bool), loss_mask=np.ones(n_nodes, bool),
        pos=rng.normal(size=(n_nodes, 3)).astype(np.float32))


def synthetic_mesh(n_nodes: int, n_edges: int, d_feat: int, d_edge: int,
                   seed: int = 0) -> GraphBatch:
    """MeshGraphNet-style batch: edge features + 3D regression targets."""
    g = synthetic_full_graph(n_nodes, n_edges // 2, d_feat, 4, seed=seed)
    rng = np.random.default_rng(seed + 1)
    e = len(g.edge_src)
    g.edge_attr = rng.normal(size=(e, d_edge)).astype(np.float32)
    g.targets = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    g.pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    return g


def synthetic_molecules(n_graphs: int, nodes_per: int, edges_per: int,
                        d_feat: int, n_classes: int, seed: int = 0
                        ) -> GraphBatch:
    """Block-diagonal batch of small graphs (graph classification)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per
    E = n_graphs * edges_per * 2
    src = np.empty(E, np.int32)
    dst = np.empty(E, np.int32)
    gid = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    for gidx in range(n_graphs):
        o = gidx * nodes_per
        s = rng.integers(0, nodes_per, size=edges_per)
        t = rng.integers(0, nodes_per, size=edges_per)
        base = gidx * edges_per * 2
        src[base:base + edges_per] = o + s
        dst[base:base + edges_per] = o + t
        src[base + edges_per:base + 2 * edges_per] = o + t
        dst[base + edges_per:base + 2 * edges_per] = o + s
    labels = rng.integers(0, n_classes, size=n_graphs).astype(np.int32)
    x = rng.normal(size=(N, d_feat)).astype(np.float32)
    x[:, 0] += labels[gid] * 0.5       # learnable signal
    pos = rng.normal(size=(N, 3)).astype(np.float32)
    return GraphBatch(
        x=x, edge_src=src, edge_dst=dst, labels=labels, n_nodes=N,
        node_mask=np.ones(N, bool),
        loss_mask=np.ones(n_graphs, bool), graph_ids=gid,
        n_graphs=n_graphs, pos=pos)


# --------------------------------------------------------------------------
# Fan-out neighbor sampler (minibatch_lg)
# --------------------------------------------------------------------------


class NeighborSampler:
    """GraphSAGE-style uniform fan-out sampler over a Graph's adjacency.

    ``sample(targets)`` returns a padded induced block: the union of sampled
    nodes (targets first), the sampled edges relabeled to block-local ids,
    padded to static (n_max, e_max). Models run all their layers on the
    induced block; the loss covers the target rows only.
    """

    def __init__(self, graph: Graph, fanouts: Sequence[int], seed: int = 0):
        self.graph = graph
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def capacity(self, batch_nodes: int) -> Tuple[int, int]:
        n = batch_nodes
        e = 0
        for f in self.fanouts:
            e += n * f
            n += n * f
        return n, e * 2

    def sample(self, targets: np.ndarray,
               n_max: Optional[int] = None,
               e_max: Optional[int] = None) -> GraphBatch:
        cap_n, cap_e = self.capacity(len(targets))
        n_max = n_max or cap_n
        e_max = e_max or cap_e
        nodes: List[int] = list(dict.fromkeys(int(t) for t in targets))
        local = {v: i for i, v in enumerate(nodes)}
        edges: List[Tuple[int, int]] = []
        frontier = list(nodes)
        for f in self.fanouts:
            nxt: List[int] = []
            for v in frontier:
                nbrs = self.graph.adj[v]
                if len(nbrs) == 0:
                    continue
                take = self.rng.choice(nbrs, size=min(f, len(nbrs)),
                                       replace=False)
                for w in take:
                    w = int(w)
                    if w not in local:
                        if len(nodes) >= n_max:
                            continue
                        local[w] = len(nodes)
                        nodes.append(w)
                        nxt.append(w)
                    edges.append((local[w], local[v]))   # message w -> v
                    edges.append((local[v], local[w]))
            frontier = nxt
        n = len(nodes)
        e = min(len(edges), e_max)
        src = np.full(e_max, n_max, np.int32)
        dst = np.full(e_max, n_max, np.int32)
        for i, (a, b) in enumerate(edges[:e]):
            src[i], dst[i] = a, b
        node_mask = np.zeros(n_max, bool)
        node_mask[:n] = True
        loss_mask = np.zeros(n_max, bool)
        loss_mask[:len(targets)] = True
        return GraphBatch(
            x=np.zeros((n_max, 0), np.float32),   # features filled by caller
            edge_src=src, edge_dst=dst,
            labels=np.zeros(n_max, np.int32), n_nodes=n_max,
            node_mask=node_mask, loss_mask=loss_mask,
        ), np.array(nodes, dtype=np.int64)

    def sample_batch(self, targets: np.ndarray, feats: np.ndarray,
                     labels: np.ndarray, n_max: int, e_max: int
                     ) -> GraphBatch:
        batch, global_ids = self.sample(targets, n_max, e_max)
        x = np.zeros((n_max, feats.shape[1]), np.float32)
        x[:len(global_ids)] = feats[global_ids]
        lb = np.zeros(n_max, np.int32)
        lb[:len(global_ids)] = labels[global_ids]
        batch.x = x
        batch.labels = lb
        return batch
