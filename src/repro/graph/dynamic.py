"""Dynamic directed data graph storage (paper §5, §6.2).

Maintains exactly the two snapshots S-BENU needs — ``G'_{t-1}`` and the
current delta sets — using the paper's two-form value design:

* between steps, a vertex value is ``(in_prev, out_prev)``;
* inside step t, touched vertices additionally carry
  ``(delta_in, delta_out)`` with per-edge flags ``{'+','-'}``.

``get_adj(v, type, direction, op)`` serves the six adjacency kinds of §5.3.1
for either snapshot; ``op='+'`` selects ``G'_t``, ``op='-'`` selects
``G'_{t-1}``, and ``(type='delta', op='*')`` returns the flagged delta set.

Six-adjacency device layout (the vectorized S-BENU substrate)
-------------------------------------------------------------
:meth:`SnapshotStore.device_snapshot` materializes the begun step as six
typed/directed padded row blocks — ``{out, in} x {prev, current, delta}`` —
each a sentinel-padded ``int32[N+1, D]`` matrix (row ``N`` is the all-holes
sentinel row so gathers with invalid ids are safe):

* ``prev_{out,in}``    rows of ``G'_{t-1}`` — serves ``(either, dir, '-')``;
* ``cur_{out,in}``     rows of ``G'_t``     — serves ``(either, dir, '+')``;
* ``delta_{out,in}``   the touched-vertex delta adjacency, value rows
  paired with ``delta_*_sign`` rows carrying the paper's ± edge flags
  (+1 insert, -1 delete, 0 hole).

The two remaining §5.3.1 kinds are derived lane-wise on device:
``unaltered = prev`` with entries flagged ``-`` masked out, and
``(delta, dir, ±)`` = the sign-filtered delta value rows. ``prev``/``cur``
blocks of one direction share a width so a per-row snapshot selector
(Delta-ENU's ``op``) is a plain ``where`` between two gathers.

:class:`DeviceSnapshotStore` keeps the resident blocks either on device
(``storage='device'``, the streaming fast path) or in host-RAM shards
(``storage='host'``, backed by :class:`~repro.graph.hoststore.HostRowStore`
— zero persistent HBM between steps, with bounded-device row serving via
:meth:`DeviceSnapshotStore.row_source` + the ``distributed/rowcache``
device cache for snapshots whose resident blocks would not fit HBM).

Example (two time steps; ``get_adj`` serves both snapshots)::

    >>> from repro.graph.storage import DiGraph
    >>> from repro.graph.dynamic import SnapshotStore
    >>> g0 = DiGraph.from_edges(4, [(0, 1), (1, 2)])
    >>> st = SnapshotStore(g0)
    >>> st.begin_step([("+", 2, 3), ("-", 0, 1)])
    >>> st.start_vertices()                  # vertices with non-empty dG_out
    [0, 2]
    >>> sorted(st.get_adj(2, "either", "out", "+"))   # G'_t
    [3]
    >>> sorted(st.get_adj(0, "either", "out", "-"))   # G'_{t-1}
    [1]
    >>> st.end_step()
    >>> sorted(st.prev.out[0])               # the merged snapshot
    []
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .storage import DiGraph, pad_rows

Update = Tuple[str, int, int]  # (op, src, dst)


@dataclass
class DeviceSnapshot:
    """The six padded adjacency blocks of one time step (numpy; the JAX
    engine registers this class as a pytree and moves it to device).

    All value blocks are sentinel-padded ``int32[N+1, D]`` with ascending
    valid entries; sign blocks are ``int32[N+1, Dd]`` aligned with
    ``delta_*`` (+1/-1, 0 at holes). ``n`` is the vertex count == sentinel.
    """

    prev_out: np.ndarray
    prev_in: np.ndarray
    cur_out: np.ndarray
    cur_in: np.ndarray
    delta_out: np.ndarray
    delta_out_sign: np.ndarray
    delta_in: np.ndarray
    delta_in_sign: np.ndarray
    n: int

    @property
    def d_out(self) -> int:
        return self.prev_out.shape[1]

    @property
    def d_in(self) -> int:
        return self.prev_in.shape[1]

    @property
    def widths(self) -> Tuple[int, ...]:
        """Static shape signature — equal widths mean no recompilation."""
        return (self.prev_out.shape[1], self.prev_in.shape[1],
                self.delta_out.shape[1], self.delta_in.shape[1])


def _with_sentinel_row(rows: np.ndarray, fill: int) -> np.ndarray:
    return np.concatenate(
        [rows, np.full((1, rows.shape[1]), fill, rows.dtype)], axis=0)


class SnapshotStore:
    """The paper's two-form vertex values for one dynamic graph (§5, §6.2).

    Holds ``prev`` (= G'_{t-1}, a :class:`DiGraph`) plus the begun step's
    delta adjacency dicts ``delta_out/delta_in`` (vertex -> {neighbor:
    '+'|'-'}). One ``begin_step(batch) ... end_step()`` bracket is one
    time step of Algorithm 4; between the two calls every §5.3.1
    adjacency kind of either snapshot is served by :meth:`get_adj`.
    """

    def __init__(self, g0: DiGraph):
        self.n = g0.n
        self.prev = g0.copy()           # G'_{t-1}
        self.delta_out: Dict[int, Dict[int, str]] = {}
        self.delta_in: Dict[int, Dict[int, str]] = {}
        self.t = 0
        self.total_queries = 0
        # device-resident mirrors notified on end_step (DeviceSnapshotStore)
        self._mirrors: List["DeviceSnapshotStore"] = []

    # ------------------------------------------------------------ time steps
    def begin_step(self, batch: Sequence[Update]) -> None:
        """Convert Δo_t into delta adjacency sets (Alg. 4 lines 7-9)."""
        self.t += 1
        self.delta_out = {}
        self.delta_in = {}
        seen: Set[Tuple[int, int]] = set()
        for op, a, b in batch:
            if (a, b) in seen:
                raise ValueError(f"edge ({a},{b}) appears twice in batch")
            seen.add((a, b))
            if op == "+" and self.prev.has_edge(a, b):
                raise ValueError(f"inserting existing edge ({a},{b})")
            if op == "-" and not self.prev.has_edge(a, b):
                raise ValueError(f"deleting missing edge ({a},{b})")
            self.delta_out.setdefault(a, {})[b] = op
            self.delta_in.setdefault(b, {})[a] = op

    def end_step(self) -> None:
        """Merge deltas into the stored snapshot (Alg. 4 line 21)."""
        for a, dd in self.delta_out.items():
            for b, op in dd.items():
                if op == "+":
                    self.prev.add_edge(a, b)
                else:
                    self.prev.remove_edge(a, b)
        for m in self._mirrors:
            m.on_host_end_step()
        self.delta_out = {}
        self.delta_in = {}

    # --------------------------------------------------------------- queries
    def start_vertices(self) -> List[int]:
        """Vertices with non-empty ΔΓ_out (Alg. 4 line 10)."""
        return sorted(self.delta_out.keys())

    def delta_adj_out(self, v: int) -> List[Tuple[str, int]]:
        """ΔΓ_out(v) as ``[('+'|'-', neighbor)]`` sorted by neighbor id."""
        dd = self.delta_out.get(v, {})
        return sorted(((op, w) for w, op in dd.items()), key=lambda x: x[1])

    def get_adj(self, v: int, type_: str, direction: str,
                op: str) -> frozenset:
        """Γ^{type,direction}_{G'_?}(v); ``?`` = t if op=='+', t-1 if op=='-'."""
        self.total_queries += 1
        prev = self.prev.out[v] if direction == "out" else self.prev.inn[v]
        dd = (self.delta_out if direction == "out" else self.delta_in
              ).get(v, {})
        inserted = {w for w, o in dd.items() if o == "+"}
        deleted = {w for w, o in dd.items() if o == "-"}
        unaltered = prev - deleted
        if type_ == "unaltered":
            return frozenset(unaltered)
        if type_ == "either":
            if op == "+":     # G'_t
                return frozenset(unaltered | inserted)
            return frozenset(prev)
        if type_ == "delta":
            if op == "+":
                return frozenset(inserted)
            return frozenset(deleted)
        raise ValueError(type_)

    # ------------------------------------------------------ device layout
    def device_snapshot(self, lane: int = 8,
                        d_min: int = 0, delta_d_min: int = 0
                        ) -> DeviceSnapshot:
        """Materialize the begun step as the six padded row blocks (host
        build, from scratch — the simple reference path; the streaming
        engine keeps a :class:`DeviceSnapshotStore` instead, which stays
        resident on device and advances incrementally).

        ``d_min``/``delta_d_min`` are width floors (rounded up to ``lane``):
        pinning them across time steps keeps the block shapes static so the
        JIT engine compiles once per stream instead of once per step.
        """
        n = self.n
        sets_by_dir = {"out": self.prev.out, "in": self.prev.inn}
        delta_by_dir = {"out": self.delta_out, "in": self.delta_in}
        blocks: Dict[str, np.ndarray] = {}
        for di in ("out", "in"):
            prev_sets = sets_by_dir[di]
            dd = delta_by_dir[di]
            prev_adj = [np.array(sorted(s), dtype=np.int64)
                        for s in prev_sets]
            cur_adj = list(prev_adj)
            for v, ops in dd.items():
                cur = set(prev_sets[v])
                for w, op in ops.items():
                    (cur.add if op == "+" else cur.discard)(w)
                cur_adj[v] = np.array(sorted(cur), dtype=np.int64)
            # prev/cur share a width so the per-row op selector is a where()
            d = max(max((len(a) for a in prev_adj), default=0),
                    max((len(a) for a in cur_adj), default=0), d_min)
            blocks[f"prev_{di}"] = _with_sentinel_row(
                pad_rows(prev_adj, n, d_max=d, lane=lane), n)
            blocks[f"cur_{di}"] = _with_sentinel_row(
                pad_rows(cur_adj, n, d_max=d, lane=lane), n)
            d_delta = max(max((len(ops) for ops in dd.values()), default=0),
                          delta_d_min)
            dvals = [np.zeros(0, dtype=np.int64)] * n
            dsigns: List[np.ndarray] = [np.zeros(0, dtype=np.int64)] * n
            for v, ops in dd.items():
                ws = sorted(ops)
                dvals[v] = np.array(ws, dtype=np.int64)
                dsigns[v] = np.array([1 if ops[w] == "+" else -1
                                      for w in ws], dtype=np.int64)
            vals = _with_sentinel_row(
                pad_rows(dvals, n, d_max=d_delta, lane=lane), n)
            signs = pad_rows(dsigns, 0, d_max=d_delta, lane=lane)
            # sign holes are 0 (pad_rows fills with its sentinel arg)
            blocks[f"delta_{di}"] = vals
            blocks[f"delta_{di}_sign"] = _with_sentinel_row(signs, 0)
        return DeviceSnapshot(n=n, **blocks)

    # ----------------------------------------------------------- test helpers
    def snapshot(self, which: str) -> DiGraph:
        """Materialize G'_t ('cur') or G'_{t-1} ('prev') — test oracle only."""
        if which == "prev":
            return self.prev.copy()
        g = self.prev.copy()
        for a, dd in self.delta_out.items():
            for b, op in dd.items():
                if op == "+":
                    g.add_edge(a, b)
                else:
                    g.remove_edge(a, b)
        return g


def stream_width_floors(g0: DiGraph, batches: Sequence[Sequence[Update]]
                        ) -> Tuple[int, int]:
    """``(d_min, delta_d_min)`` pinning snapshot widths over a whole known
    update stream, so the JIT engine compiles once instead of retracing
    whenever a step's max degree or delta degree drifts."""
    cur = g0.copy()
    d = max(max((len(s) for s in cur.out), default=0),
            max((len(s) for s in cur.inn), default=0))
    dd = 0
    for batch in batches:
        touched_out: Dict[int, int] = {}
        touched_in: Dict[int, int] = {}
        for op, a, b in batch:
            touched_out[a] = touched_out.get(a, 0) + 1
            touched_in[b] = touched_in.get(b, 0) + 1
            if op == "+":
                cur.add_edge(a, b)
            else:
                cur.remove_edge(a, b)
        dd = max(dd, max(touched_out.values(), default=0),
                 max(touched_in.values(), default=0))
        d = max(d, max((len(s) for s in cur.out), default=0),
                max((len(s) for s in cur.inn), default=0))
    return d, dd


class DeviceSnapshotStore:
    """Device-resident dual-snapshot row store (the streaming fast path).

    Keeps the ``prev`` blocks resident on device across time steps and
    advances them incrementally, so per-step host work is O(|ΔE|) instead
    of an O(N) Python rebuild:

    * :meth:`step_snapshot` (store begun): scatter the update batch into
      the delta value/sign buffers (vectorized COO build), then derive
      ``G'_t`` **on device, touched rows only**: gather the |ΔV| touched
      prev rows, mask deleted entries, merge the inserted delta values
      (concat + row sort + slice back to width D — the merged row fits by
      the width guard), and scatter them into a copy of the prev block.
      Per-step device cost is O(|ΔV|·D) plus one O(N·D) memcpy, not a
      full-graph masked sort.
    * end_step (via the :class:`SnapshotStore` mirror hook): the merged
      snapshot IS the cur block, so promotion is free buffer adoption
      (``prev <- cur``). Width overflow drops the mirror; the next step
      rebuilds with wider rows.

    Rebuild triggers (all O(N), rare): first use, a touched row outgrowing
    the pinned width, or the host store advancing without this mirror
    (e.g. interpreter steps in between).

    ``storage`` selects where the resident ``prev`` blocks live:

    * ``'device'`` (default): jax arrays on device — fastest per step, but
      the dual snapshot must fit HBM;
    * ``'host'``: :class:`~repro.graph.hoststore.HostRowStore` shards in
      host RAM, advanced **in place** by patching only the touched rows at
      ``end_step`` (O(|ΔV|·D) host work — no O(N) rebuild, no persistent
      device residency). :meth:`step_snapshot` still materializes full
      numpy blocks for the resident jit engine (compat path, transferred
      per step and freed after); :meth:`row_source` serves per-row
      ``prev``/``cur`` views for the bounded-device cache fetch path
      (``distributed/rowcache.py``) so row serving never needs the full
      block on device.
    """

    def __init__(self, store: SnapshotStore, lane: int = 8,
                 d_min: int = 0, delta_d_min: int = 0,
                 storage: str = "device"):
        import jax
        import jax.numpy as jnp
        if storage not in ("device", "host"):
            raise ValueError(f"storage must be device|host, got {storage!r}")
        self.host = store
        self.n = store.n
        self.storage = storage
        self.params = (lane, d_min, delta_d_min, storage)
        self.lane, self.d_min, self.delta_d_min = lane, d_min, delta_d_min
        self._jnp = jnp
        # device blocks carry this many rows: n real + 1 sentinel (the
        # mesh-sharded subclass pads further so shards divide evenly; rows
        # beyond n are all-sentinel and never gathered — clip(ids, 0, n))
        self._rows_total = store.n + 1
        # di -> jax [N+1, D] (device mode) | HostRowStore (host mode)
        self._prev: Optional[Dict[str, object]] = None
        self._d: Dict[str, int] = {}
        self._cur: Dict[str, object] = {}
        # host mode: di -> (touched ids int64[K], merged rows int32[K, D])
        self._cur_host: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        self._pending_t: Optional[int] = None
        self.rebuilds = 0

        def derive(prev, tids, dvals, dsigns):
            """cur block from prev + the touched rows' delta (tids are
            sentinel-padded: padding rewrites the sentinel row with
            itself). Merged rows stay sorted with tail holes, so the
            engines' binary-search intersect b-side invariant holds."""
            d = prev.shape[1]
            rows = prev[tids]                       # [K, D]
            dv = dvals[tids]                        # [K, Dd]
            ds = dsigns[tids]
            deleted = jnp.where(ds < 0, dv, self.n)
            hit = jnp.any(rows[:, :, None] == deleted[:, None, :], axis=2)
            unalt = jnp.where(hit, self.n, rows)
            plus = jnp.where(ds > 0, dv, self.n)
            merged = jnp.sort(jnp.concatenate([unalt, plus], axis=1),
                              axis=1)[:, :d]        # fits: width guard
            return prev.at[tids].set(merged)

        self._derive_fn = derive
        self._derive = jax.jit(derive)
        store._mirrors.append(self)

    def _place(self, arr: np.ndarray):
        """Device placement of one block (subclass hook: the mesh-sharded
        store device_puts with a row-partitioned NamedSharding here)."""
        return self._jnp.asarray(arr)

    @classmethod
    def for_store(cls, store: SnapshotStore, lane: int = 8,
                  d_min: int = 0, delta_d_min: int = 0,
                  storage: str = "device") -> "DeviceSnapshotStore":
        """Reuse an existing mirror with the same layout parameters."""
        for m in store._mirrors:
            if isinstance(m, cls) and m.params == (lane, d_min,
                                                   delta_d_min, storage):
                return m
        return cls(store, lane=lane, d_min=d_min, delta_d_min=delta_d_min,
                   storage=storage)

    def _round(self, x: int) -> int:
        return ((max(x, 1) + self.lane - 1) // self.lane) * self.lane

    def _rebuild_prev(self) -> None:
        """Full host build of the resident prev blocks (stream start or
        width overflow); accounts for this step's inserts so cur fits.
        Device mode materializes jax ``[N+1, D]`` blocks; host mode builds
        :class:`HostRowStore` shards (one shard transient at a time)."""
        from .hoststore import HostRowStore
        self.rebuilds += 1
        n, jnp = self.n, self._jnp
        self._prev = {}
        for di, sets, delta in (("out", self.host.prev.out,
                                 self.host.delta_out),
                                ("in", self.host.prev.inn,
                                 self.host.delta_in)):
            need = max((len(sets[v])
                        + sum(1 for op in ops.values() if op == "+")
                        for v, ops in delta.items()), default=0)
            d = self._round(max(max((len(s) for s in sets), default=0),
                                need, self.d_min))
            if self.storage == "host":
                self._prev[di] = HostRowStore.from_adj(
                    lambda v: sorted(sets[v]), n, d)
            else:
                rows = np.full((self._rows_total, d), n, np.int32)
                for v, s in enumerate(sets):
                    a = sorted(s)
                    rows[v, :len(a)] = a
                self._prev[di] = self._place(rows)
            self._d[di] = d

    def _delta_buffers(self, delta: Dict[int, Dict[int, str]]
                       ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Vectorized COO scatter of one direction's delta dicts into
        fresh value/sign buffers."""
        n = self.n
        items = [(v, w, 1 if op == "+" else -1)
                 for v, ops in delta.items() for w, op in ops.items()]
        if not items:
            dd = self._round(self.delta_d_min)
            return (np.full((self._rows_total, dd), n, np.int32),
                    np.zeros((self._rows_total, dd), np.int32), 0)
        arr = np.asarray(items, np.int64)
        arr = arr[np.lexsort((arr[:, 1], arr[:, 0]))]
        src = arr[:, 0]
        gstart = np.flatnonzero(np.r_[True, src[1:] != src[:-1]])
        counts = np.diff(np.r_[gstart, len(src)])
        pos = np.arange(len(src)) - np.repeat(gstart, counts)
        dd = self._round(max(int(counts.max()), self.delta_d_min))
        vals = np.full((self._rows_total, dd), n, np.int32)
        signs = np.zeros((self._rows_total, dd), np.int32)
        vals[src, pos] = arr[:, 1]
        signs[src, pos] = arr[:, 2]
        return vals, signs, int(counts.max())

    def _derive_host(self, store, delta: Dict[int, Dict[int, str]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side merge of the touched rows: ``(tids int64[K],
        merged int32[K, D])`` — G'_t rows for exactly the touched
        vertices, O(|ΔV|·D) work (the numpy twin of the device
        ``derive``)."""
        n = self.n
        touched = np.asarray(sorted(delta), np.int64)
        if touched.size == 0:
            return touched, np.zeros((0, store.d), np.int32)
        rows = store.gather(touched)
        for i, v in enumerate(touched):
            ops = delta[int(v)]
            cur = {int(x) for x in rows[i] if x != n}
            for w, op in ops.items():
                (cur.add if op == "+" else cur.discard)(w)
            a = sorted(cur)
            rows[i] = n
            rows[i, :len(a)] = a       # fits: step_snapshot width guard
        return touched, rows

    def _ensure_prev_fits(self) -> None:
        """Width guard shared by every per-step entry point: a touched row
        of G'_t outgrowing the pinned width forces a wider rebuild
        (deletes only shrink rows)."""
        st = self.host
        if self._prev is not None:
            for di, sets, delta in (("out", st.prev.out, st.delta_out),
                                    ("in", st.prev.inn, st.delta_in)):
                if any(len(sets[v]) + sum(1 for op in ops.values()
                                          if op == "+") > self._d[di]
                       for v, ops in delta.items()):
                    self._prev = None
                    break
        if self._prev is None:
            self._rebuild_prev()

    def _ensure_step_cur_host(self) -> None:
        """Host mode: derive (and cache) both directions' merged touched
        rows for the begun step, once per step — row_source() and
        step_snapshot() share this state, and setting ``_pending_t``
        makes ``end_step`` patch the shards in place instead of
        discarding them."""
        st = self.host
        self._ensure_prev_fits()
        if self._pending_t == st.t and len(self._cur_host) == 2:
            return
        self._cur_host = {
            di: self._derive_host(self._prev[di], delta)
            for di, delta in (("out", st.delta_out), ("in", st.delta_in))}
        self._pending_t = st.t

    def step_snapshot(self) -> DeviceSnapshot:
        """Six blocks for the host store's begun step, derived on device."""
        st = self.host
        if self.storage == "host":
            # host mode: merge touched rows on host (O(|ΔV|·D)), assemble
            # numpy blocks for the resident engine (compat path — the
            # bounded-device path serves rows via row_source() instead)
            self._ensure_step_cur_host()
            blocks_h: Dict[str, np.ndarray] = {}
            for di, delta in (("out", st.delta_out), ("in", st.delta_in)):
                vals, signs, _ = self._delta_buffers(delta)
                hs = self._prev[di]
                tids, merged = self._cur_host[di]
                prev_full = hs.to_rows()
                cur_full = prev_full.copy()
                if tids.size:
                    cur_full[tids] = merged
                blocks_h[f"prev_{di}"] = prev_full
                blocks_h[f"cur_{di}"] = cur_full
                blocks_h[f"delta_{di}"] = vals
                blocks_h[f"delta_{di}_sign"] = signs
            return DeviceSnapshot(n=self.n, **blocks_h)
        self._ensure_prev_fits()
        jnp = self._jnp
        blocks: Dict[str, object] = {}
        for di, delta in (("out", st.delta_out), ("in", st.delta_in)):
            vals, signs, _ = self._delta_buffers(delta)
            jvals, jsigns = self._place(vals), self._place(signs)
            # touched ids, sentinel-padded to a power of two so steps with
            # similar churn share one compiled derive shape
            touched = sorted(delta)
            k = 1 << max(len(touched) - 1, 0).bit_length()
            tids = np.full(max(k, 1), self.n, np.int32)
            tids[:len(touched)] = touched
            cur = self._derive(self._prev[di], jnp.asarray(tids), jvals,
                               jsigns)
            self._cur[di] = cur
            blocks[f"prev_{di}"] = self._prev[di]
            blocks[f"cur_{di}"] = cur
            blocks[f"delta_{di}"] = jvals
            blocks[f"delta_{di}_sign"] = jsigns
        self._pending_t = st.t
        return DeviceSnapshot(n=self.n, **blocks)

    def on_host_end_step(self) -> None:
        """SnapshotStore mirror hook (post-merge): promote cur -> prev.

        Device mode adopts the derived cur buffers; host mode patches the
        touched rows back into the host shards in place (O(|ΔV|·D))."""
        st = self.host
        if self._prev is None:
            return
        if self._pending_t != st.t:
            self._prev = None            # store advanced without us
            return
        for di, sets, delta in (("out", st.prev.out, st.delta_out),
                                ("in", st.prev.inn, st.delta_in)):
            if any(len(sets[v]) > self._d[di] for v in delta):
                self._prev = None        # merged row overflows: rebuild
                return
        if self.storage == "host":
            for di in ("out", "in"):
                tids, merged = self._cur_host.get(
                    di, (np.zeros(0, np.int64), None))
                if tids.size:
                    self._prev[di].set_rows(tids, merged)
            self._cur_host = {}
            self._pending_t = None
            return
        for di in ("out", "in"):
            self._prev[di] = self._cur[di]   # promotion is buffer adoption
        self._cur = {}
        self._pending_t = None

    # ------------------------------------------------- bounded row serving
    def row_source(self, direction: str, which: str = "cur"
                   ) -> "SnapshotRowView":
        """A :class:`HostRowStore`-shaped view over one resident block.

        Host mode only (device mode already has the block resident).
        ``which='prev'`` serves G'_{t-1} rows straight from the shards;
        ``which='cur'`` overlays the begun step's merged touched rows.
        Feed the view to ``distributed.rowcache.DeviceRowCache`` to serve
        snapshot rows with bounded device residency — the fetch path for
        streams whose resident blocks would not fit HBM.

        Coherence across steps: ``end_step`` patches the backing shards
        **in place**, so a ``DeviceRowCache`` kept alive across steps
        must be told — call ``cache.invalidate(touched_ids)`` after
        ``end_step`` (only ``'prev'`` views are meaningful to keep; a
        ``'cur'`` view's overlay is per-step by construction, so request
        a fresh one via this method each step). A *rebuild* of the
        resident shards (``self.rebuilds`` increments: width overflow,
        or the host store advancing without this mirror) replaces the
        backing store wholesale — rebuild any long-lived cache when that
        counter changes. The view itself always resolves the mirror's
        current store, so it never serves an orphaned pre-rebuild copy.
        """
        if self.storage != "host":
            raise ValueError("row_source() requires storage='host'")
        if which == "prev":
            self._ensure_prev_fits()
            return SnapshotRowView(self, direction, {})
        if which != "cur":
            raise ValueError(f"which must be prev|cur, got {which!r}")
        # derives once per step (both directions) and marks the step
        # pending, so end_step patches the shards in place — the bounded
        # path gets the same O(|ΔV|·D) advance as step_snapshot users
        self._ensure_step_cur_host()
        tids, merged = self._cur_host[direction]
        return SnapshotRowView(
            self, direction,
            {int(v): merged[i] for i, v in enumerate(tids)})


@dataclass(frozen=True)
class SnapshotShardSpec:
    """Static layout of a mesh-sharded six-block snapshot.

    Duck-compatible with the ``distributed/rowstore.py`` fetch builder
    (``n`` / ``n_shards`` / ``rows_per_shard`` / ``hot``): every block is
    block-partitioned by row over the enumeration axis (owner of row v =
    ``v // rows_per_shard``), widths vary per block and are read from the
    arrays at trace time. The ``hot`` highest ids (``>= n - hot``) are
    additionally replicated on every device and served locally. Note:
    unlike the static path, streaming graphs are **not** degree-relabeled
    at load, so the replicated set is an id range, only a hub set if the
    stream's vertex numbering makes it one — relabel the initial graph
    (and stream) by ascending degree to get the static engine's anti-skew
    behavior.
    """

    n: int                 # real vertices; sentinel value
    n_shards: int
    rows_per_shard: int    # ceil((n+1) / n_shards); blocks carry S*rps rows
    hot: int = 0


class ShardedDeviceSnapshotStore(DeviceSnapshotStore):
    """Mesh-sharded resident dual-snapshot store (the distributed
    streaming substrate, core/engine_sbenu_dist.py).

    Same per-step contract as the device-mode base class — resident
    ``prev`` blocks advanced incrementally, ``cur`` derived from
    ``prev`` + delta for the touched rows only, promotion by buffer
    adoption at ``end_step`` — but every block is laid out with
    ``S * rows_per_shard`` rows and device_put with a row-partitioned
    ``NamedSharding`` over the enumeration mesh, so the dual snapshot's
    HBM footprint is split S ways and the per-step derive runs as one
    GSPMD program over the sharded buffers.

    :meth:`step_sharded` additionally materializes the per-direction
    **joint delta block** (values ++ signs, one fetch per delta DBQ) and
    the replicated hot-row slices the SPMD engine serves locally.

    Snapshots from this store feed the ``shard_map`` engine; they are
    *not* interchangeable with the single-device engine's snapshots (row
    counts differ from ``n + 1`` — gathers still work, but there is no
    point paying the mesh layout without the mesh).
    """

    def __init__(self, store: SnapshotStore, mesh, axis: str = "shard",
                 lane: int = 8, d_min: int = 0, delta_d_min: int = 0,
                 hot: int = 0):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        self.mesh, self.axis = mesh, axis
        self.S = int(mesh.devices.size)
        super().__init__(store, lane=lane, d_min=d_min,
                         delta_d_min=delta_d_min, storage="device")
        self.rows_per_shard = -(-(store.n + 1) // self.S)
        self._rows_total = self.S * self.rows_per_shard
        self.hot = min(int(hot), store.n)
        self._jax = jax
        self._sh2d = NamedSharding(mesh, PartitionSpec(axis, None))
        self._rep2d = NamedSharding(mesh, PartitionSpec(None, None))
        # re-jit the shared derive with the row-partitioned output layout
        self._derive = jax.jit(self._derive_fn, out_shardings=self._sh2d)
        self.params = (lane, d_min, delta_d_min, "sharded", self.S,
                       axis, self.hot)

    @classmethod
    def for_store(cls, store: SnapshotStore, mesh, axis: str = "shard",
                  lane: int = 8, d_min: int = 0, delta_d_min: int = 0,
                  hot: int = 0) -> "ShardedDeviceSnapshotStore":
        """Reuse an existing sharded mirror with the same layout + mesh."""
        key = (lane, d_min, delta_d_min, "sharded", int(mesh.devices.size),
               axis, min(int(hot), store.n))
        for m in store._mirrors:
            if isinstance(m, cls) and m.params == key and m.mesh is mesh:
                return m
        return cls(store, mesh, axis=axis, lane=lane, d_min=d_min,
                   delta_d_min=delta_d_min, hot=hot)

    def _place(self, arr: np.ndarray):
        return self._jax.device_put(np.asarray(arr), self._sh2d)

    def step_sharded(self) -> Tuple[Dict[str, object], Dict[str, object],
                                    SnapshotShardSpec]:
        """``(blocks, hot_blocks, spec)`` for the begun step.

        ``blocks``: six row-partitioned device arrays — ``prev_/cur_{out,
        in}`` plus ``delta_joint_{out,in}`` (values ++ signs concatenated
        along the width, so one request/response exchange serves a whole
        flagged delta row). ``hot_blocks``: the replicated ``[hot+1, W]``
        top-id slices of each (the ``+1`` is the sentinel row, matching
        ``distributed/rowstore.py``).
        """
        jnp = self._jnp
        snap = self.step_snapshot()
        blocks: Dict[str, object] = {
            "prev_out": snap.prev_out, "cur_out": snap.cur_out,
            "prev_in": snap.prev_in, "cur_in": snap.cur_in,
            "delta_joint_out": self._jax.device_put(
                jnp.concatenate([snap.delta_out, snap.delta_out_sign],
                                axis=1), self._sh2d),
            "delta_joint_in": self._jax.device_put(
                jnp.concatenate([snap.delta_in, snap.delta_in_sign],
                                axis=1), self._sh2d),
        }
        lo = self.n - self.hot
        hot_blocks = {k: self._jax.device_put(v[lo:self.n + 1], self._rep2d)
                      for k, v in blocks.items()}
        spec = SnapshotShardSpec(n=self.n, n_shards=self.S,
                                 rows_per_shard=self.rows_per_shard,
                                 hot=self.hot)
        return blocks, hot_blocks, spec


class SnapshotRowView:
    """Read-only ``HostRowStore``-API view over one direction of a
    host-mode :class:`DeviceSnapshotStore`, plus per-step row patches.

    Duck-types the three members ``DeviceRowCache`` needs (``n``, ``d``,
    ``gather``); ``patches`` maps vertex id -> replacement row
    (``int32[d]``, sentinel-padded). The backing shards are resolved
    through the mirror on every access, so a width rebuild swaps in the
    new store here transparently (callers holding a ``DeviceRowCache``
    over the view still need to rebuild it then — the cached row width
    changes; see :meth:`DeviceSnapshotStore.row_source`).
    """

    def __init__(self, mirror: "DeviceSnapshotStore", direction: str,
                 patches: Dict[int, np.ndarray]):
        self.mirror = mirror
        self.direction = direction
        self.patches = patches
        self.n = mirror.n

    @property
    def base(self):
        return self.mirror._prev[self.direction]

    @property
    def d(self) -> int:
        return self.base.d

    def gather(self, ids: np.ndarray) -> np.ndarray:
        """Dense ``int32[K, d]`` rows with patches applied (clip
        semantics identical to :meth:`HostRowStore.gather`)."""
        out = self.base.gather(ids)
        if self.patches:
            flat = np.clip(np.asarray(ids, np.int64).reshape(-1), 0, self.n)
            for i, v in enumerate(flat):
                p = self.patches.get(int(v))
                if p is not None:
                    out[i] = p
        return out
