"""Dynamic directed data graph storage (paper §5, §6.2).

Maintains exactly the two snapshots S-BENU needs — ``G'_{t-1}`` and the
current delta sets — using the paper's two-form value design:

* between steps, a vertex value is ``(in_prev, out_prev)``;
* inside step t, touched vertices additionally carry
  ``(delta_in, delta_out)`` with per-edge flags ``{'+','-'}``.

``get_adj(v, type, direction, op)`` serves the six adjacency kinds of §5.3.1
for either snapshot; ``op='+'`` selects ``G'_t``, ``op='-'`` selects
``G'_{t-1}``, and ``(type='delta', op='*')`` returns the flagged delta set.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .storage import DiGraph

Update = Tuple[str, int, int]  # (op, src, dst)


class SnapshotStore:
    def __init__(self, g0: DiGraph):
        self.n = g0.n
        self.prev = g0.copy()           # G'_{t-1}
        self.delta_out: Dict[int, Dict[int, str]] = {}
        self.delta_in: Dict[int, Dict[int, str]] = {}
        self.t = 0
        self.total_queries = 0

    # ------------------------------------------------------------ time steps
    def begin_step(self, batch: Sequence[Update]) -> None:
        """Convert Δo_t into delta adjacency sets (Alg. 4 lines 7-9)."""
        self.t += 1
        self.delta_out = {}
        self.delta_in = {}
        seen: Set[Tuple[int, int]] = set()
        for op, a, b in batch:
            if (a, b) in seen:
                raise ValueError(f"edge ({a},{b}) appears twice in batch")
            seen.add((a, b))
            if op == "+" and self.prev.has_edge(a, b):
                raise ValueError(f"inserting existing edge ({a},{b})")
            if op == "-" and not self.prev.has_edge(a, b):
                raise ValueError(f"deleting missing edge ({a},{b})")
            self.delta_out.setdefault(a, {})[b] = op
            self.delta_in.setdefault(b, {})[a] = op

    def end_step(self) -> None:
        """Merge deltas into the stored snapshot (Alg. 4 line 21)."""
        for a, dd in self.delta_out.items():
            for b, op in dd.items():
                if op == "+":
                    self.prev.add_edge(a, b)
                else:
                    self.prev.remove_edge(a, b)
        self.delta_out = {}
        self.delta_in = {}

    # --------------------------------------------------------------- queries
    def start_vertices(self) -> List[int]:
        """Vertices with non-empty ΔΓ_out (Alg. 4 line 10)."""
        return sorted(self.delta_out.keys())

    def delta_adj_out(self, v: int) -> List[Tuple[str, int]]:
        dd = self.delta_out.get(v, {})
        return sorted(((op, w) for w, op in dd.items()), key=lambda x: x[1])

    def get_adj(self, v: int, type_: str, direction: str,
                op: str) -> frozenset:
        """Γ^{type,direction}_{G'_?}(v); ``?`` = t if op=='+', t-1 if op=='-'."""
        self.total_queries += 1
        prev = self.prev.out[v] if direction == "out" else self.prev.inn[v]
        dd = (self.delta_out if direction == "out" else self.delta_in
              ).get(v, {})
        inserted = {w for w, o in dd.items() if o == "+"}
        deleted = {w for w, o in dd.items() if o == "-"}
        unaltered = prev - deleted
        if type_ == "unaltered":
            return frozenset(unaltered)
        if type_ == "either":
            if op == "+":     # G'_t
                return frozenset(unaltered | inserted)
            return frozenset(prev)
        if type_ == "delta":
            if op == "+":
                return frozenset(inserted)
            return frozenset(deleted)
        raise ValueError(type_)

    # ----------------------------------------------------------- test helpers
    def snapshot(self, which: str) -> DiGraph:
        """Materialize G'_t ('cur') or G'_{t-1} ('prev') — test oracle only."""
        if which == "prev":
            return self.prev.copy()
        g = self.prev.copy()
        for a, dd in self.delta_out.items():
            for b, op in dd.items():
                if op == "+":
                    g.add_edge(a, b)
                else:
                    g.remove_edge(a, b)
        return g
