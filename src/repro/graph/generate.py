"""Synthetic data-graph generators (deterministic, numpy-only core).

The paper evaluates on SNAP graphs (as-Skitter, LiveJournal, ...) which are
not available offline; we generate Erdős–Rényi and power-law
(Barabási–Albert-style preferential attachment) graphs of configurable size —
the two regimes that matter for BENU (uniform vs heavy-tail degree skew,
which drives the task-splitting experiments).
"""

from __future__ import annotations

from typing import List, Set, Tuple

import numpy as np

from .storage import DiGraph, Graph


def erdos_renyi(n: int, m: int, seed: int = 0,
                canonicalize: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    edges: Set[Tuple[int, int]] = set()
    while len(edges) < m:
        need = m - len(edges)
        a = rng.integers(0, n, size=2 * need + 8)
        b = rng.integers(0, n, size=2 * need + 8)
        for x, y in zip(a, b):
            if x == y:
                continue
            e = (min(int(x), int(y)), max(int(x), int(y)))
            edges.add(e)
            if len(edges) >= m:
                break
    return Graph.from_edges(n, list(edges), canonicalize=canonicalize)


def powerlaw(n: int, m_per_node: int = 4, seed: int = 0,
             canonicalize: bool = True) -> Graph:
    """Barabási–Albert preferential attachment."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    repeated: List[int] = list(range(m_per_node))
    edges: Set[Tuple[int, int]] = set()
    for v in range(m_per_node, n):
        for t in targets:
            e = (min(v, t), max(v, t))
            edges.add(e)
            repeated.extend([v, t])
        targets = [int(repeated[i])
                   for i in rng.integers(0, len(repeated), size=m_per_node)]
        targets = list(dict.fromkeys(targets))[:m_per_node]
        while len(targets) < m_per_node:
            t = int(rng.integers(0, v))
            if t not in targets:
                targets.append(t)
    return Graph.from_edges(n, list(edges), canonicalize=canonicalize)


def random_digraph(n: int, m: int, seed: int = 0) -> DiGraph:
    rng = np.random.default_rng(seed)
    g = DiGraph(n)
    added = 0
    while added < m:
        a = int(rng.integers(0, n))
        b = int(rng.integers(0, n))
        if a != b and not g.has_edge(a, b):
            g.add_edge(a, b)
            added += 1
    return g


def edge_stream(n: int, m_init: int, steps: int, batch: int, seed: int = 0,
                delete_frac: float = 0.3):
    """A dynamic directed graph: initial DiGraph + per-step batch updates.

    Returns ``(g0, [batch_1, ..., batch_steps])`` where each batch is a list
    of ``(op, src, dst)`` with op in {'+', '-'}, each edge appearing at most
    once per batch (paper's assumption).
    """
    rng = np.random.default_rng(seed)
    g0 = random_digraph(n, m_init, seed=seed)
    cur = g0.copy()
    batches = []
    for _ in range(steps):
        ops = []
        touched = set()
        existing = list(cur.edges())
        n_del = min(int(batch * delete_frac), max(len(existing) - 1, 0))
        if n_del and existing:
            idx = rng.choice(len(existing), size=n_del, replace=False)
            for i in idx:
                a, b = existing[int(i)]
                if (a, b) in touched:
                    continue
                ops.append(("-", a, b))
                touched.add((a, b))
        while len(ops) < batch:
            a = int(rng.integers(0, n))
            b = int(rng.integers(0, n))
            if a == b or cur.has_edge(a, b) or (a, b) in touched:
                continue
            ops.append(("+", a, b))
            touched.add((a, b))
        for op, a, b in ops:     # advance the generator's view
            if op == "+":
                cur.add_edge(a, b)
            else:
                cur.remove_edge(a, b)
        batches.append(ops)
    return g0, batches


def toy_graph_fig1() -> Graph:
    """A small graph akin to Fig. 1(b) for doc examples/tests (8 vertices)."""
    edges = [(0, 1), (0, 2), (0, 3), (0, 4), (0, 6), (0, 7), (1, 2), (2, 3),
             (3, 4), (4, 7), (1, 6), (2, 6), (4, 5), (5, 7)]
    return Graph.from_edges(8, edges, canonicalize=False)
