"""HostRowStore: the padded adjacency in host-RAM shards (out-of-core).

The vectorized engines consume sentinel-padded adjacency rows
(``int32[N+1, D]``, row ``N`` = the all-holes sentinel row). Keeping that
matrix resident in device memory caps the data-graph size at HBM; the
paper's answer (§6) is a *pull* model — tasks query rows on demand from a
distributed store and a local cache absorbs repeats. This module is the
host half of that model for a single machine:

* rows live in **host RAM**, block-partitioned into shards of
  ``rows_per_shard`` rows each (``int32[rps, D]`` numpy arrays). The full
  ``[N+1, D]`` matrix is never materialized as one device array — shards
  are built directly from the per-vertex adjacency lists, one shard at a
  time, so peak transient memory during the build is one shard;
* :meth:`HostRowStore.gather` serves an id batch as a dense ``[K, D]``
  block — the unit the device row cache (``distributed/rowcache.py``)
  moves over PCIe/ICI. Ids ``>= n`` (the sentinel and anything padded)
  round-trip to the sentinel row, mirroring ``DeviceGraph`` gathers;
* :meth:`HostRowStore.set_rows` rewrites individual rows in place — the
  streaming snapshot store advances ``G'_{t-1} -> G'_t`` by patching only
  the touched rows (O(|ΔV|·D) host work per time step).

Shard layout matches ``distributed/rowstore.py``'s block partition
(owner = id // rows_per_shard), so the same store can back either the
single-host device cache or a future multi-host fetch service.
"""

from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .storage import DiGraph, Graph, padded_width

DEFAULT_ROWS_PER_SHARD = 4096


class HostRowStore:
    """Sentinel-padded adjacency rows sharded over host RAM.

    Logical shape is ``int32[n + 1, d]``: one row per vertex plus the
    all-sentinel row at index ``n``. Physically the rows live in
    ``ceil((n + 1) / rows_per_shard)`` numpy shards of
    ``rows_per_shard`` rows each (the last shard is short, never padded).
    """

    def __init__(self, shards: List[np.ndarray], n: int,
                 rows_per_shard: int):
        if not shards:
            raise ValueError("need at least one shard")
        self.shards = shards
        self.n = n                          # real vertices; sentinel value
        self.rows_per_shard = rows_per_shard
        self.d = shards[0].shape[1]

    # ------------------------------------------------------------- builders
    @staticmethod
    def from_adj(adj_of: Callable[[int], Sequence[int]], n: int, d: int,
                 rows_per_shard: int = DEFAULT_ROWS_PER_SHARD
                 ) -> "HostRowStore":
        """Build shard by shard from an ``id -> sorted neighbors`` callable.

        ``d`` must already be the final padded width (callers round up to
        their lane multiple). Only one shard is under construction at any
        moment — the full ``[n + 1, d]`` block never exists contiguously.
        """
        rps = max(int(rows_per_shard), 1)
        shards: List[np.ndarray] = []
        for lo in range(0, n + 1, rps):
            hi = min(lo + rps, n + 1)
            shard = np.full((hi - lo, d), n, np.int32)
            for v in range(lo, min(hi, n)):     # row n stays all-sentinel
                a = adj_of(v)
                if len(a) > d:
                    raise ValueError(
                        f"row {v} has {len(a)} entries > padded width {d}")
                shard[v - lo, :len(a)] = a
            shards.append(shard)
        return HostRowStore(shards, n, rps)

    @staticmethod
    def from_graph(graph: Graph, d_max: Optional[int] = None, lane: int = 8,
                   rows_per_shard: int = DEFAULT_ROWS_PER_SHARD
                   ) -> "HostRowStore":
        """Host shards of ``graph``'s undirected padded adjacency.

        Same row semantics as ``DeviceGraph.from_graph`` (``engine_jax``):
        width = max degree (or ``d_max``) rounded up to ``lane``.
        """
        max_len = int(graph.deg.max()) if graph.n else 0
        d = padded_width(max_len, d_max=d_max, lane=lane, strict=True)
        return HostRowStore.from_adj(lambda v: graph.adj[v], graph.n, d,
                                     rows_per_shard=rows_per_shard)

    @staticmethod
    def from_digraph(g: DiGraph, direction: str = "out",
                     d_max: Optional[int] = None, lane: int = 8,
                     rows_per_shard: int = DEFAULT_ROWS_PER_SHARD
                     ) -> "HostRowStore":
        """Host shards of one adjacency direction of a directed graph."""
        sets = g.out if direction == "out" else g.inn
        max_len = max((len(s) for s in sets), default=0)
        d = padded_width(max_len, d_max=d_max, lane=lane, strict=True)
        return HostRowStore.from_adj(lambda v: sorted(sets[v]), g.n, d,
                                     rows_per_shard=rows_per_shard)

    # -------------------------------------------------------------- queries
    @property
    def n_rows(self) -> int:
        """Stored rows including the sentinel row (``n + 1``)."""
        return self.n + 1

    @property
    def nbytes(self) -> int:
        """Host bytes held by the shards."""
        return sum(s.nbytes for s in self.shards)

    def row(self, v: int) -> np.ndarray:
        """One row (a *view* into its shard; copy before mutating)."""
        v = min(max(int(v), 0), self.n)
        return self.shards[v // self.rows_per_shard][v % self.rows_per_shard]

    def gather(self, ids: np.ndarray) -> np.ndarray:
        """Dense ``int32[K, d]`` block for ``ids`` (any shape flattened).

        Ids are clipped to ``[0, n]`` — the device gathers' semantics:
        ids ``>= n`` (sentinel / padding) return the sentinel row,
        negative ids clamp to row 0.
        """
        ids = np.clip(np.asarray(ids, np.int64).reshape(-1), 0, self.n)
        out = np.empty((ids.shape[0], self.d), np.int32)
        shard_of = ids // self.rows_per_shard
        local = ids % self.rows_per_shard
        for s in np.unique(shard_of):
            m = shard_of == s
            out[m] = self.shards[s][local[m]]
        return out

    def set_rows(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Overwrite rows in place (streaming snapshot advance).

        ``rows`` is ``int32[K, d]`` already sentinel-padded; ids must be
        real vertices (``0 <= id < n`` — the sentinel row is immutable).
        """
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n):
            raise ValueError("set_rows ids must be real vertices")
        rows = np.asarray(rows, np.int32)
        shard_of = ids // self.rows_per_shard
        local = ids % self.rows_per_shard
        for s in np.unique(shard_of):
            m = shard_of == s
            self.shards[s][local[m]] = rows[m]

    def to_rows(self) -> np.ndarray:
        """The full ``[n + 1, d]`` block (test oracle / compat path only —
        this is exactly the materialization the store exists to avoid)."""
        return np.concatenate(self.shards, axis=0)
