"""Data-graph storage substrate.

The paper stores adjacency sets in a distributed KV database keyed by vertex
id. Our in-memory logical form mirrors that: per-vertex *sorted* adjacency
arrays. Two physical layouts are provided:

* ``Graph`` / ``DiGraph``: python/numpy adjacency lists — used by the plan
  compiler, the reference engine and the dynamic-graph machinery.
* ``padded_adjacency``: a dense ``int32[N, D]`` row matrix padded with the
  sentinel ``N`` — the device-resident layout consumed by the JAX engines and
  the DistributedRowStore (rows are what DBQ fetches).

**Total order / symmetry breaking**: the paper uses a degree-based total
order on V(G) for static graphs. We *relabel* vertices by ``(degree, id)``
ascending at load time (``canonicalize=True``) so that the total order is the
natural integer order — symmetry-breaking filters compile to plain integer
compares on both CPU and TPU.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.estimate import GraphStats

Edge = Tuple[int, int]


def padded_width(max_len: int, d_max: Optional[int] = None, lane: int = 8,
                 strict: bool = False) -> int:
    """The one padded-row width rule: ``max(d_max or max_len, 1)`` rounded
    up to a multiple of ``lane``. ``strict=True`` raises when ``d_max``
    is below ``max_len`` (callers that refuse truncation outright, e.g.
    the host row store)."""
    if strict and d_max is not None and d_max < max_len:
        raise ValueError(f"d_max={d_max} below the max degree {max_len}")
    d = max_len if d_max is None else d_max
    d = max(d, 1)
    return ((d + lane - 1) // lane) * lane


def pad_rows(adj: Sequence[np.ndarray], sentinel: int,
             d_max: Optional[int] = None, lane: int = 8,
             on_overflow: str = "raise") -> np.ndarray:
    """Pack per-vertex sorted arrays into a sentinel-padded ``int32[N, D]``.

    ``D`` is ``max(d_max or max-len, 1)`` rounded up to a multiple of
    ``lane``. When a row is longer than the final width ``D`` (so entries
    would actually be dropped), ``on_overflow`` decides: ``"raise"``
    (default) fails, ``"clamp"`` keeps the first ``D`` entries and emits a
    ``RuntimeWarning`` — never a silent truncation.
    """
    max_len = max((len(a) for a in adj), default=0)
    d = padded_width(max_len, d_max=d_max, lane=lane)
    if max_len > d:
        overfull = sum(1 for a in adj if len(a) > d)
        msg = (f"padded rows truncated: {overfull} row(s) exceed the "
               f"padded width {d} (longest has {max_len} entries)")
        if on_overflow == "raise":
            raise ValueError(msg + "; pass on_overflow='clamp' to truncate")
        if on_overflow != "clamp":
            raise ValueError(f"unknown on_overflow={on_overflow!r}")
        warnings.warn(msg, RuntimeWarning, stacklevel=2)
    rows = np.full((len(adj), d), sentinel, dtype=np.int32)
    for v, a in enumerate(adj):
        a = a[:d]
        rows[v, :len(a)] = a
    return rows


class Graph:
    """Static undirected simple graph with sorted adjacency arrays."""

    def __init__(self, n: int, adj: List[np.ndarray],
                 relabel: Optional[np.ndarray] = None):
        self.n = n
        self.adj = adj                      # adj[v]: sorted int64 array
        self.relabel = relabel              # original id -> canonical id
        self.deg = np.array([len(a) for a in adj], dtype=np.int64)

    # ------------------------------------------------------------- builders
    @staticmethod
    def from_edges(n: int, edges: Iterable[Edge],
                   canonicalize: bool = True) -> "Graph":
        nbr: List[set] = [set() for _ in range(n)]
        for a, b in edges:
            if a == b:
                continue
            nbr[a].add(b)
            nbr[b].add(a)
        if canonicalize:
            deg = np.array([len(s) for s in nbr])
            # vertices sorted by (degree, id) ascending; rank = new id
            order = np.lexsort((np.arange(n), deg))
            relabel = np.empty(n, dtype=np.int64)
            relabel[order] = np.arange(n)
            adj = [None] * n  # type: ignore
            for v in range(n):
                adj[relabel[v]] = np.array(
                    sorted(relabel[w] for w in nbr[v]), dtype=np.int64)
            return Graph(n, adj, relabel)
        adj = [np.array(sorted(s), dtype=np.int64) for s in nbr]
        return Graph(n, adj)

    # -------------------------------------------------------------- queries
    def neighbors(self, v: int) -> np.ndarray:
        return self.adj[v]

    def has_edge(self, a: int, b: int) -> bool:
        arr = self.adj[a]
        i = np.searchsorted(arr, b)
        return i < len(arr) and arr[i] == b

    @property
    def m(self) -> int:
        return int(self.deg.sum() // 2)

    def stats(self) -> GraphStats:
        return GraphStats(n_vertices=self.n, n_edges=self.m)

    def edges(self) -> Iterable[Edge]:
        for v in range(self.n):
            for w in self.adj[v]:
                if v < w:
                    yield (v, int(w))

    # ---------------------------------------------------------- dense layout
    def padded_adjacency(self, d_max: Optional[int] = None,
                         lane: int = 8, on_overflow: str = "raise"
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """``(rows int32[N, D], deg int32[N])`` padded with sentinel N.

        ``D`` is rounded up to a multiple of ``lane`` for friendly layouts
        (the Pallas kernel wants a multiple of 128; callers pass lane=128).
        A ``d_max`` below the real maximum degree raises by default;
        ``on_overflow='clamp'`` truncates with a RuntimeWarning instead.
        """
        rows = pad_rows(self.adj, self.n, d_max=d_max, lane=lane,
                        on_overflow=on_overflow)
        return rows, self.deg.astype(np.int32)


class DiGraph:
    """Static directed simple graph (S-BENU snapshots)."""

    def __init__(self, n: int):
        self.n = n
        self.out: List[set] = [set() for _ in range(n)]
        self.inn: List[set] = [set() for _ in range(n)]

    @staticmethod
    def from_edges(n: int, edges: Iterable[Edge]) -> "DiGraph":
        g = DiGraph(n)
        for a, b in edges:
            g.add_edge(a, b)
        return g

    def add_edge(self, a: int, b: int) -> None:
        if a == b:
            return
        self.out[a].add(b)
        self.inn[b].add(a)

    def remove_edge(self, a: int, b: int) -> None:
        self.out[a].discard(b)
        self.inn[b].discard(a)

    def has_edge(self, a: int, b: int) -> bool:
        return b in self.out[a]

    def copy(self) -> "DiGraph":
        g = DiGraph(self.n)
        g.out = [set(s) for s in self.out]
        g.inn = [set(s) for s in self.inn]
        return g

    @property
    def m(self) -> int:
        return sum(len(s) for s in self.out)

    def edges(self) -> Iterable[Edge]:
        for v in range(self.n):
            for w in sorted(self.out[v]):
                yield (v, w)

    def stats(self) -> GraphStats:
        return GraphStats(n_vertices=self.n, n_edges=self.m)

    # ---------------------------------------------------------- dense layout
    def padded_adjacency(self, direction: str = "out",
                         d_max: Optional[int] = None, lane: int = 8,
                         on_overflow: str = "raise") -> np.ndarray:
        """Sentinel-padded ``int32[N, D]`` rows of one adjacency direction."""
        sets = self.out if direction == "out" else self.inn
        adj = [np.array(sorted(s), dtype=np.int64) for s in sets]
        return pad_rows(adj, self.n, d_max=d_max, lane=lane,
                        on_overflow=on_overflow)


def edge_index_from_graph(g: Graph) -> np.ndarray:
    """``int32[2, 2m]`` symmetric COO edge index (GNN substrate)."""
    src, dst = [], []
    for v in range(g.n):
        for w in g.adj[v]:
            src.append(v)
            dst.append(int(w))
    return np.array([src, dst], dtype=np.int32)
