# Compute hot-spot kernels (paper §4.3.1: INT dominates the cost model).
# Public entry points live in ops.py; impl resolution / tile table /
# operand padding in dispatch.py; pure-jnp oracles in ref.py. Inventory +
# the "how to add a kernel" recipe: docs/KERNELS.md.
