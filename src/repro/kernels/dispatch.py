"""Kernel dispatch + autotune layer: one registry for every kernels/ op.

Before this module, each public op in :mod:`repro.kernels.ops` carried its
own copy of the dispatch policy — an ``_on_tpu()`` probe here, a
``REPRO_INTERSECT_IMPL`` read there, a third copy of the mixed-width
operand padding in the streaming engine. This module centralizes all of
it:

* **impl resolution** (:func:`resolve_impl`) — one order for every op:
  an explicit ``impl=`` argument always wins; ``auto`` consults the op's
  environment override (``REPRO_<OP>_IMPL``, e.g. ``REPRO_INTERSECT_IMPL``
  — the CI hook that forces the Pallas path in interpret mode on the CPU
  container); otherwise the registry's platform × width default applies.
* **tile selection** (:func:`pick_tiles`) — the benchmark-driven
  ``(bm, bk)`` table per op and platform (``bm`` rows per block along the
  batch axis, ``bk`` lanes per chunk along the set-width axis), with
  per-call overrides, clamped so ``bk`` divides the padded width and
  ``bm`` divides the batch.
* **operand padding** (:func:`pad_operands`) — the mixed-width padding
  the Pallas kernels need (both operands to a common lane width, batch to
  a ``bm`` multiple, holes sentinel-filled so padding never adds set
  members), previously duplicated at three call sites.

See ``docs/KERNELS.md`` for the kernel inventory and the "how to add a
kernel" recipe built on :func:`register_op`.

Example — the resolution order, end to end::

    >>> import os
    >>> from repro.kernels import dispatch
    >>> _ = os.environ.pop("REPRO_INTERSECT_IMPL", None)   # clean slate
    >>> dispatch.resolve_impl("intersect", "pallas-interpret")  # alias
    'interpret'
    >>> dispatch.resolve_impl("intersect", "auto", platform="tpu")
    'pallas'
    >>> dispatch.resolve_impl("intersect", "auto", platform="cpu", width=64)
    'ref'
    >>> dispatch.resolve_impl("intersect", "auto", platform="cpu",
    ...                       width=1024)                  # wide rows: O(D)
    'chunked'
    >>> os.environ["REPRO_INTERSECT_IMPL"] = "pallas-interpret"
    >>> dispatch.resolve_impl("intersect")         # env overrides 'auto' ...
    'interpret'
    >>> dispatch.resolve_impl("intersect", "binary")  # ... explicit wins
    'binary'
    >>> _ = os.environ.pop("REPRO_INTERSECT_IMPL", None)
    >>> dispatch.pick_tiles("intersect", batch=64, width=256)
    (8, 128)
    >>> # odd width: bk falls back to the full row (callers pad batch to bm)
    >>> dispatch.pick_tiles("intersect", batch=7, width=200)
    (8, 200)
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

#: spellings accepted everywhere an ``impl=`` is taken (CLI, env, code)
IMPL_ALIASES = {"pallas-interpret": "interpret"}

#: a platform default: an impl name, or a callable ``width -> impl name``
#: (``width`` may be None when the caller has no shape at hand)
Default = Union[str, Callable[[Optional[int]], str]]


@dataclass(frozen=True)
class OpSpec:
    """Registry entry: the impls an op accepts + its platform defaults."""

    name: str
    impls: Tuple[str, ...]
    defaults: Dict[str, Default]         # platform ('*' fallback) -> Default
    env: str                             # environment override variable


_OPS: Dict[str, OpSpec] = {}


def register_op(name: str, impls: Tuple[str, ...],
                defaults: Dict[str, Default],
                env: Optional[str] = None) -> OpSpec:
    """Register a kernel op with the dispatcher.

    ``impls`` are the accepted ``impl=`` names (``auto`` and the
    ``pallas-interpret`` alias are implicit). ``defaults`` maps platform
    names (``jax.default_backend()`` values; ``'*'`` as fallback) to an
    impl name or a ``width -> impl`` callable. ``env`` defaults to
    ``REPRO_<NAME>_IMPL``.
    """
    spec = OpSpec(name=name, impls=tuple(impls), defaults=dict(defaults),
                  env=env or f"REPRO_{name.upper()}_IMPL")
    _OPS[name] = spec
    return spec


def op_spec(op: str) -> OpSpec:
    try:
        return _OPS[op]
    except KeyError:
        raise ValueError(f"unknown kernel op {op!r}; registered: "
                         f"{sorted(_OPS)}") from None


def _normalize(spec: OpSpec, impl: str) -> str:
    impl = IMPL_ALIASES.get(impl, impl)
    if impl != "auto" and impl not in spec.impls:
        raise ValueError(
            f"{spec.name}: unknown impl {impl!r}; choose from "
            f"{('auto',) + spec.impls} (or alias "
            f"{sorted(IMPL_ALIASES)})")
    return impl


def resolve_impl(op: str, impl: str = "auto",
                 platform: Optional[str] = None,
                 width: Optional[int] = None) -> str:
    """Resolve ``impl`` for ``op``: explicit > env override > registry.

    The single resolution order every public op follows (the bug class
    this kills: ops that read the env but ignored an explicit argument,
    or probed the platform but ignored the env). ``platform`` defaults to
    ``jax.default_backend()``; ``width`` feeds width-dependent defaults
    (e.g. the CPU intersect switches to the O(D)-memory chunked scan on
    wide rows).
    """
    spec = op_spec(op)
    impl = _normalize(spec, impl)
    if impl != "auto":
        return impl
    env_val = os.environ.get(spec.env, "").strip()
    if env_val:
        resolved = _normalize(spec, env_val)
        if resolved != "auto":
            return resolved
    platform = platform or jax.default_backend()
    default = spec.defaults.get(platform, spec.defaults["*"])
    if callable(default):
        default = default(width)
    return _normalize(spec, default)


# --------------------------------------------------------------------------
# Tile-size table (the autotune layer)
# --------------------------------------------------------------------------

#: benchmark-driven (bm, bk) per op x platform, bucketed by set width:
#: ``(max_width_inclusive | None, bm, bk)`` rows, first match wins. The TPU
#: rows follow the VMEM budget math in kernels/sorted_intersect.py (compare
#: working set = bm * W * bk bools; <= ~4MiB on a 16MiB v5e core); the
#: ``'*'`` rows were measured with ``benchmarks/roofline.py --fused`` in
#: interpret mode on the 2-core CI container (wider bk only pays off once
#: rows exceed ~1k lanes). Override per call via pick_tiles(bm=, bk=).
TILE_TABLE: Dict[str, Dict[str, Tuple[Tuple[Optional[int], int, int], ...]]] = {
    "intersect": {
        "tpu": ((512, 8, 128), (2048, 8, 256), (None, 4, 256)),
        "*": ((None, 8, 128),),
    },
    "gather_intersect": {
        "tpu": ((1024, 8, 128), (None, 8, 256)),
        "gpu": ((None, 16, 128),),
        "*": ((None, 8, 128),),
    },
}


def pick_tiles(op: str, batch: int, width: int,
               platform: Optional[str] = None,
               bm: Optional[int] = None,
               bk: Optional[int] = None) -> Tuple[int, int]:
    """``(bm, bk)`` for a ``[batch, width]`` problem on ``platform``.

    Units: ``bm`` counts frontier rows per kernel block (batch axis);
    ``bk`` counts int32 lanes per inner-loop chunk (set-width axis).
    Explicit ``bm``/``bk`` are taken verbatim except for the width clamp:
    ``bk`` must divide ``width`` (falls back to 128 | width, then
    ``width`` itself). ``bm`` is returned as-is from the table — the
    kernels require ``batch % bm == 0``, and every ops.py wrapper pads
    the batch up to a ``bm`` multiple *after* picking tiles
    (:func:`pad_operands` / :func:`pad_to_multiple`; a handful of
    sentinel rows beats shrinking the block to ``bm=1`` and multiplying
    the grid steps).
    """
    table = TILE_TABLE[op]
    rows = table.get(platform or jax.default_backend(), table["*"])
    tbm, tbk = rows[-1][1:]
    for wmax, rbm, rbk in rows:
        if wmax is None or width <= wmax:
            tbm, tbk = rbm, rbk
            break
    bm = bm if bm is not None else tbm
    bk = bk if bk is not None else tbk
    if width % bk != 0:
        bk = 128 if width % 128 == 0 else width
    return bm, bk


# --------------------------------------------------------------------------
# Shared operand padding (mixed widths, batch multiples)
# --------------------------------------------------------------------------


def pad_to(x: jax.Array, axis: int, size: int, fill) -> jax.Array:
    """Pad ``x`` along ``axis`` up to ``size`` entries with ``fill``."""
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def pad_to_multiple(x: jax.Array, axis: int, multiple: int,
                    fill) -> jax.Array:
    """Pad ``x`` along ``axis`` up to the next ``multiple`` with ``fill``."""
    size = x.shape[axis]
    return pad_to(x, axis, size + ((-size) % multiple), fill)


def pad_operands(a: jax.Array, b: jax.Array, sentinel: int,
                 bm: int) -> Tuple[jax.Array, jax.Array]:
    """Pad a mixed-width operand pair for a row-blocked Pallas kernel.

    Both rows are padded to the wider width and the batch to a ``bm``
    multiple; every hole is sentinel-valued, so padding never adds set
    members (the padded-set invariant of kernels/ref.py). This is the one
    copy of the logic previously repeated in ops.py's Pallas branch, the
    streaming engine's impl resolver, and the width-matching fetch.
    """
    w = max(a.shape[-1], b.shape[-1])
    ap = pad_to_multiple(pad_to(a, 1, w, sentinel), 0, bm, sentinel)
    bp = pad_to_multiple(pad_to(b, 1, w, sentinel), 0, bm, sentinel)
    return ap, bp


# --------------------------------------------------------------------------
# Fused-fetch toggle (engine-level, not per-op)
# --------------------------------------------------------------------------


def fused_fetch_enabled(default: bool = False) -> bool:
    """Whether engines should fuse DBQ gathers into the intersect kernel.

    ``REPRO_FUSED_FETCH`` forces it on (``1``/``on``/``true``) or off
    (``0``/``off``/``false``) for the static frontier backends (``jax`` /
    ``jax-gpu`` — currently the only consumers; the streaming and OOC
    engines have no device-resident adjacency gather to fuse yet, see
    the ROADMAP follow-ups) — the CI hook that runs the fast tier-1
    profile through the fused path. Unset, ``default`` applies (True for
    the ``jax-gpu`` backend, False elsewhere).
    """
    val = os.environ.get("REPRO_FUSED_FETCH", "").strip().lower()
    if val in ("1", "on", "true", "yes"):
        return True
    if val in ("0", "off", "false", "no"):
        return False
    return default


# --------------------------------------------------------------------------
# The built-in ops (kernels/ops.py maps these names to callables)
# --------------------------------------------------------------------------


def _cpu_intersect_default(width: Optional[int]) -> str:
    # wide rows: the O(D)-memory chunked scan; narrow: the dense probe
    return "chunked" if (width or 0) > 512 else "ref"


register_op("intersect",
            impls=("ref", "chunked", "binary", "pallas", "interpret"),
            defaults={"tpu": "pallas", "*": _cpu_intersect_default},
            env="REPRO_INTERSECT_IMPL")
register_op("gather_intersect",
            impls=("ref", "chunked", "binary", "pallas", "interpret"),
            defaults={"tpu": "pallas", "gpu": "pallas", "*": "ref"})
register_op("flash_attention",
            impls=("ref", "pallas", "interpret"),
            defaults={"tpu": "pallas", "*": "ref"})
register_op("rmsnorm",
            impls=("ref", "pallas", "interpret"),
            defaults={"tpu": "pallas", "*": "ref"})
