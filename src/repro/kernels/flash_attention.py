"""Pallas TPU kernel: flash attention (online-softmax tiling, causal, GQA).

The LM hot-spot for the train_4k / prefill_32k cells. Classic q-block x
kv-block streaming: f32 running max / sum / accumulator live in VMEM scratch;
KV is consumed block-by-block so the [Tq, Tk] score matrix never hits HBM.
Tiles are 128-aligned for the MXU. GQA is handled by mapping each q-head to
its kv-head in the grid index map (no KV repeat materialized).

Grid: (batch * q_heads, Tq / bq, Tk / bk) — the kv axis is the innermost
(sequential on TPU) dimension, so scratch accumulators carry across kv steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  tq: int, tk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0].astype(jnp.float32)            # [bk, d]
    v = v_ref[0].astype(jnp.float32)            # [bk, d]
    s = jnp.dot(q, k.T) * scale                 # [bq, bk]
    if causal:
        # query row r (global qi*bq + r) attends keys <= r + (tk - tq)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos + (tk - tq), s, NEG_INF)

    m_prev = m_ref[...]                         # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                      # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)         # fully-masked rows -> 0 out
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Tq, d]; k, v: [B, Hkv, Tk, d] -> [B, Hq, Tq, d]."""
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0, (tq, bq, tk, bk)

    qf = q.reshape(b * hq, tq, d)
    kf = k.reshape(b * hkv, tk, d)
    vf = v.reshape(b * hkv, tk, d)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        # map flat q-head h = bi * hq + hqi to kv row bi * hkv + hqi // group
        bi = h // hq
        hi = h % hq
        return (bi * hkv + hi // group, j, 0)

    grid = (b * hq, tq // bq, tk // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, tq=tq, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, tq, d)
