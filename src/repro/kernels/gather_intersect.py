"""Fused Pallas kernel: DBQ-level row gather + padded-set intersection.

The accelerator fetch path of the ROADMAP. BENU's hot loop is
``rows = adjacency[ids]; cand = cand ∩ rows`` — one DBQ gather feeding one
INT per frontier level. Executed separately (engine_jax's unfused path)
the gather materializes a ``[B, D]`` row block in HBM that the intersect
immediately re-reads: 3x the row bytes over the minimum. This kernel fuses
the two: each addressed adjacency row is DMA'd HBM -> VMEM exactly once by
the Pallas pipeline and consumed from VMEM by the membership probe — the
gathered block never exists in HBM.

Design (TPU-native; CI covers it via ``interpret=True`` on CPU)
---------------------------------------------------------------
The gather uses the scalar-prefetch idiom: ``ids`` ride in SMEM
(``PrefetchScalarGridSpec``) and the adjacency's BlockSpec *index map*
addresses row ``ids[i*bm + j]`` directly, so the pipeline fetches exactly
the rows the frontier asks for — arbitrary order, duplicates included —
while ``pallas_call``'s double buffering overlaps row ``k+1``'s DMA with
row ``k``'s probe. The grid is ``(B // bm, bm)``: the candidate/output
``[bm, Dc]`` blocks are revisited across the ``bm`` inner steps (flushed
to HBM once per outer step), each inner step probing one candidate row
against one fetched adjacency row in ``bk``-lane chunks — the same
VPU-friendly broadcast-compare inner loop as kernels/sorted_intersect.py
(``[1, Dc, bk]`` equality reduce), with the same VMEM budget math.

Sentinel-awareness: ``ids`` must be pre-clipped to ``[0, n]`` (row ``n``
is the all-sentinel row — ops.fused_gather_intersect does this), and rows
addressed by a sentinel id skip the probe entirely and write an
all-sentinel output row (an invalid frontier row can never gain members).
Holes never create members: a candidate hole equals the sentinel, which
the validity mask removes regardless of the compare.

Output keeps matching candidate entries **in place** (holes = sentinel),
so results remain valid padded sets — exactly ``intersect_padded(cand,
adjacency[ids])`` bit for bit, which is what tests/test_gpu_fetch.py's
property test pins.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_intersect_kernel(ids_ref, cand_ref, adj_ref, o_ref, *,
                             sentinel: int, bk: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bm = pl.num_programs(1)
    cand = cand_ref[pl.dslice(j, 1), :]                 # [1, Dc]
    rid = ids_ref[i * bm + j]
    nchunks = adj_ref.shape[1] // bk

    @pl.when(rid < sentinel)
    def _probe():
        def body(k, member):
            chunk = adj_ref[:, pl.dslice(k * bk, bk)]   # [1, bk]
            eq = cand[:, :, None] == chunk[:, None, :]  # [1, Dc, bk]
            return member | jnp.any(eq, axis=-1)

        member = jax.lax.fori_loop(
            0, nchunks, body, jnp.zeros(cand.shape, dtype=jnp.bool_))
        o_ref[pl.dslice(j, 1), :] = jnp.where(
            (cand != sentinel) & member, cand, sentinel)

    @pl.when(rid >= sentinel)
    def _sentinel_row():
        o_ref[pl.dslice(j, 1), :] = jnp.full_like(cand, sentinel)


@functools.partial(jax.jit, static_argnames=("sentinel", "bm", "bk",
                                             "interpret"))
def gather_intersect_pallas(ids: jax.Array, cand: jax.Array,
                            adj: jax.Array, sentinel: int,
                            bm: int = 8, bk: int = 128,
                            interpret: bool = False) -> jax.Array:
    """``cand[i] ∩ adj[ids[i]]`` per row, gather fused into the probe.

    ids: int32[B] row indices, pre-clipped to ``[0, sentinel]``;
    cand: int32[B, Dc] padded sets; adj: int32[N+1, D] padded adjacency
    (row N all-sentinel). Returns int32[B, Dc] in ``cand``'s slots.
    ``D`` must be a multiple of ``bk`` and ``B`` of ``bm`` (callers pad;
    see ops.fused_gather_intersect).
    """
    B, Dc = cand.shape
    D = adj.shape[1]
    assert ids.shape == (B,), (ids.shape, cand.shape)
    assert D % bk == 0, f"D={D} not a multiple of bk={bk}"
    assert B % bm == 0, f"B={B} not a multiple of bm={bm}"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // bm, bm),
        in_specs=[
            pl.BlockSpec((bm, Dc), lambda i, j, ids: (i, 0)),
            # the fused gather: the index map addresses the adjacency row
            # the frontier asks for — no materialized [B, D] intermediate
            pl.BlockSpec((1, D), lambda i, j, ids: (ids[i * bm + j], 0)),
        ],
        out_specs=pl.BlockSpec((bm, Dc), lambda i, j, ids: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gather_intersect_kernel, sentinel=sentinel,
                          bk=bk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Dc), cand.dtype),
        interpret=interpret,
    )(ids, cand, adj)
