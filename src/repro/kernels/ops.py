"""jit'd public wrappers around the Pallas kernels with jnp fallbacks.

All callers in the model/engine code go through this module so the
implementation can be swapped per-backend without touching call sites.
Dispatch is owned by :mod:`repro.kernels.dispatch` — one resolution order
for every op (explicit ``impl=`` argument > ``REPRO_<OP>_IMPL``
environment override > platform × width registry default), one tile-size
table, one mixed-width operand-padding helper. ``pallas-interpret`` (as
an argument or an env value) runs the Pallas kernel in interpret mode on
any backend — the CI hook that keeps the TPU/GPU kernel paths
conformance-tested on the CPU container. See ``docs/KERNELS.md`` for the
kernel inventory and tiling knobs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import dispatch, ref
from .flash_attention import flash_attention_pallas
from .gather_intersect import gather_intersect_pallas
from .rmsnorm import rmsnorm_pallas
from .sorted_intersect import sorted_intersect_pallas


# --------------------------------------------------------------------------
# intersect
# --------------------------------------------------------------------------


def _check_binary_operands(a: jax.Array, b: jax.Array, sentinel: int) -> None:
    """Loud precondition check for ``impl='binary'``.

    The binary-search probe requires 2-D operands with matching batch and
    ``b`` rows *fully ascending* with holes only in the tail (fresh DBQ
    rows are; INT results carry in-place holes — keep those on the ``a``
    side, or resort; see kernels/ref.py). Violations used to surface as
    an opaque vmap/searchsorted shape error or, worse, silently wrong
    memberships; now they raise a ValueError up front. The sortedness
    check only runs on concrete (non-traced) arrays — inside jit the
    caller's invariant is trusted.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
        raise ValueError(
            "impl='binary' needs 2-D operands with a shared batch: got "
            f"a{tuple(a.shape)}, b{tuple(b.shape)}; pad/stack rows first "
            "(dispatch.pad_operands) or use impl='ref'")
    if not isinstance(b, jax.core.Tracer):
        rows = jnp.asarray(b)
        if rows.size and bool(jnp.any(rows[:, 1:] < rows[:, :-1])):
            raise ValueError(
                "impl='binary' needs b rows fully ascending with holes "
                "only in the tail (sentinel-padded DBQ rows); this b has "
                "out-of-order entries or interspersed holes — resort "
                "(jnp.sort(b, axis=-1)) or use impl='ref'/'chunked'")


def intersect_padded(a: jax.Array, b: jax.Array, sentinel: int,
                     impl: str = "auto", bm: Optional[int] = None,
                     bk: Optional[int] = None) -> jax.Array:
    """Row-wise padded-set intersection; see kernels/ref.py for semantics.

    a: int32[B, Da], b: int32[B, Db] (widths may differ — the Pallas path
    pads both operands to the wider width via dispatch.pad_operands;
    holes are sentinel-valued so padding never adds members). ``impl``:
    auto | pallas | ref | chunked | binary | interpret (alias
    ``pallas-interpret``); resolution order in kernels/dispatch.py.
    ``binary`` needs ``b`` rows fully ascending (holes only in the tail)
    and raises ValueError on concrete violations. ``bm``/``bk`` override
    the tile table (rows per block / lanes per chunk).
    """
    impl = dispatch.resolve_impl("intersect", impl, width=a.shape[-1])
    if impl == "ref":
        return ref.sorted_intersect(a, b, sentinel)
    if impl == "chunked":
        return ref.sorted_intersect_chunked(a, b, sentinel)
    if impl == "binary":
        _check_binary_operands(a, b, sentinel)
        return ref.sorted_intersect_binary(a, b, sentinel)
    interpret = impl == "interpret"
    B, Da = a.shape
    W = max(Da, b.shape[1])
    bm, bk = dispatch.pick_tiles("intersect", B, W, bm=bm, bk=bk)
    ap, bp = dispatch.pad_operands(a, b, sentinel, bm)
    out = sorted_intersect_pallas(ap, bp, sentinel, bm=bm, bk=bk,
                                  interpret=interpret)
    return out[:B, :Da]


# --------------------------------------------------------------------------
# fused gather + intersect (the GPU/TPU fetch path)
# --------------------------------------------------------------------------


def fused_gather_intersect(cand: jax.Array, ids: jax.Array,
                           rows: jax.Array, sentinel: int,
                           impl: str = "auto", bm: Optional[int] = None,
                           bk: Optional[int] = None) -> jax.Array:
    """``cand[i] ∩ rows[ids[i]]`` without materializing ``rows[ids]``.

    The DBQ-level gather and the candidate-set intersection in one kernel
    launch: cand int32[B, Dc] padded sets, ids int32[B] frontier row
    indices (any values — clipped to the sentinel row), rows int32[N+1, D]
    padded adjacency whose row N is all-sentinel. Returns int32[B, Dc] in
    ``cand``'s slots — bit-equal to
    ``intersect_padded(cand, rows[clip(ids)], sentinel)``.

    ``impl``: auto | pallas | interpret fuse on device
    (kernels/gather_intersect.py); ref | chunked | binary fall back to
    gather-then-intersect with that intersect impl (the unfused reference
    the property tests compare against). ``auto`` resolves via the
    dispatch registry (``REPRO_GATHER_INTERSECT_IMPL`` env override;
    pallas on tpu/gpu, ref elsewhere).
    """
    impl = dispatch.resolve_impl("gather_intersect", impl,
                                 width=rows.shape[-1])
    ids = jnp.clip(ids, 0, sentinel)
    if impl in ("ref", "chunked", "binary"):
        return intersect_padded(cand, rows[ids], sentinel, impl=impl)
    interpret = impl == "interpret"
    B, Dc = cand.shape
    D = rows.shape[1]
    bm, bk = dispatch.pick_tiles("gather_intersect", B, D, bm=bm, bk=bk)
    cp = dispatch.pad_to_multiple(cand, 0, bm, sentinel)
    ip = dispatch.pad_to_multiple(ids, 0, bm, sentinel)
    out = gather_intersect_pallas(ip, cp, rows, sentinel, bm=bm, bk=bk,
                                  interpret=interpret)
    return out[:B]


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: Optional[float] = None,
                    impl: str = "auto") -> jax.Array:
    """q: [B, Hq, Tq, d]; k, v: [B, Hkv, Tk, d] -> [B, Hq, Tq, d].

    ``auto`` resolves via the dispatch registry (explicit impl >
    ``REPRO_FLASH_ATTENTION_IMPL`` > pallas on TPU, ref elsewhere).
    """
    impl = dispatch.resolve_impl("flash_attention", impl)
    if impl == "ref":
        return ref.flash_attention(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  interpret=(impl == "interpret"))


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
            impl: str = "auto") -> jax.Array:
    """RMSNorm over the last axis; arbitrary leading dims.

    ``auto`` resolves via the dispatch registry (explicit impl >
    ``REPRO_RMSNORM_IMPL`` > pallas on TPU, ref elsewhere).
    """
    impl = dispatch.resolve_impl("rmsnorm", impl)
    if impl == "ref":
        return ref.rmsnorm(x, gamma, eps)
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    bm = 256
    while rows % bm != 0:
        bm //= 2
    out = rmsnorm_pallas(x2, gamma, eps=eps, bm=max(bm, 1),
                         interpret=(impl == "interpret"))
    return out.reshape(shape)
