"""jit'd public wrappers around the Pallas kernels with jnp fallbacks.

Dispatch policy: ``impl='auto'`` selects the Pallas kernel on TPU backends
and the pure-jnp reference elsewhere (this container is CPU-only; Pallas
TPU kernels are exercised via ``interpret=True`` in tests). All callers in
the model/engine code go through this module so the implementation can be
swapped per-backend without touching call sites.

The environment variable ``REPRO_INTERSECT_IMPL`` overrides the ``auto``
choice for the intersect (an explicit ``impl=`` argument always wins);
``REPRO_INTERSECT_IMPL=pallas-interpret`` runs the Pallas kernel in
interpret mode on any backend — the CI hook that keeps the TPU INT path
conformance-tested on the CPU container.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas
from .sorted_intersect import sorted_intersect_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, fill) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


# --------------------------------------------------------------------------
# intersect
# --------------------------------------------------------------------------


def intersect_padded(a: jax.Array, b: jax.Array, sentinel: int,
                     impl: str = "auto") -> jax.Array:
    """Row-wise padded-set intersection; see kernels/ref.py for semantics.

    a: int32[B, Da], b: int32[B, Db] (widths may differ — the Pallas path
    pads both operands to the wider width; holes are sentinel-valued so
    padding never adds members). ``impl``: auto | pallas | ref | chunked |
    binary | interpret (alias ``pallas-interpret``). ``binary`` needs
    ``b`` rows fully ascending (holes only in the tail) — see
    kernels/ref.py. ``auto`` honours ``REPRO_INTERSECT_IMPL``.
    """
    if impl == "auto":
        impl = os.environ.get("REPRO_INTERSECT_IMPL", "").strip() or "auto"
    if impl == "pallas-interpret":
        impl = "interpret"
    if impl == "auto":
        impl = "pallas" if _on_tpu() else ("chunked" if a.shape[-1] > 512
                                           else "ref")
    if impl == "ref":
        return ref.sorted_intersect(a, b, sentinel)
    if impl == "chunked":
        return ref.sorted_intersect_chunked(a, b, sentinel)
    if impl == "binary":
        return ref.sorted_intersect_binary(a, b, sentinel)
    interpret = impl == "interpret"
    B, Da = a.shape
    W = max(Da, b.shape[1])
    bm = 8 if B % 8 == 0 else 1
    bk = 128 if W % 128 == 0 else W
    ap = _pad_to(_pad_to(a, 1, W, sentinel), 0, bm, sentinel)
    bp = _pad_to(_pad_to(b, 1, W, sentinel), 0, bm, sentinel)
    out = sorted_intersect_pallas(ap, bp, sentinel, bm=bm, bk=bk,
                                  interpret=interpret)
    return out[:B, :Da]


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: Optional[float] = None,
                    impl: str = "auto") -> jax.Array:
    """q: [B, Hq, Tq, d]; k, v: [B, Hkv, Tk, d] -> [B, Hq, Tq, d]."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.flash_attention(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  interpret=(impl == "interpret"))


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
            impl: str = "auto") -> jax.Array:
    """RMSNorm over the last axis; arbitrary leading dims."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ref.rmsnorm(x, gamma, eps)
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    bm = 256
    while rows % bm != 0:
        bm //= 2
    out = rmsnorm_pallas(x2, gamma, eps=eps, bm=max(bm, 1),
                         interpret=(impl == "interpret"))
    return out.reshape(shape)
