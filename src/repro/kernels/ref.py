"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the semantics-defining implementations: each Pallas kernel in this
package is validated against the function of the same name here (interpret
mode on CPU, sweeps over shapes/dtypes in ``tests/test_kernels.py``).

Padded-set convention (BENU substrate)
--------------------------------------
A vertex set is an ``int32[D]`` row. Entries equal to the *sentinel* (the
number of real vertices, ``N``) are holes; valid entries are strictly
ascending among themselves. Intersection keeps entries of ``a`` that are
members of ``b`` **in place** (order- and position-preserving), so results
stay valid padded sets without compaction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# sorted_intersect
# --------------------------------------------------------------------------


def sorted_intersect(a: jax.Array, b: jax.Array, sentinel: int) -> jax.Array:
    """Row-wise padded-set intersection ``a ∩ b`` (kept in ``a``'s slots).

    a, b: int32[..., D] padded sets. Returns int32[..., D].
    """
    member = jnp.any(a[..., :, None] == b[..., None, :], axis=-1)
    valid = a != sentinel
    return jnp.where(valid & member, a, sentinel)


def sorted_intersect_binary(a: jax.Array, b: jax.Array,
                            sentinel: int) -> jax.Array:
    """Membership by per-row binary search: O(Da log Db) instead of the
    probe's O(Da * Db).

    Requirement: ``b`` rows must be fully ascending with holes only in the
    tail (fresh DBQ rows are; INT results are not — keep them on the ``a``
    side, which tolerates interspersed holes). The engines' fold order
    ``res = isect(res, fresh_row)`` satisfies this by construction.
    """
    idx = jax.vmap(jnp.searchsorted)(b, a)
    idx = jnp.clip(idx, 0, b.shape[-1] - 1)
    found = jnp.take_along_axis(b, idx, axis=-1) == a
    return jnp.where((a != sentinel) & found, a, sentinel)


def sorted_intersect_chunked(a: jax.Array, b: jax.Array, sentinel: int,
                             chunk: int = 128) -> jax.Array:
    """Same semantics, O(D) memory: scan over b in chunks (used by the
    pure-jnp engines when D is large; the Pallas kernel tiles the same way
    in VMEM)."""
    d = b.shape[-1]
    pad = (-d) % chunk
    if pad:
        b = jnp.concatenate(
            [b, jnp.full(b.shape[:-1] + (pad,), sentinel, b.dtype)], axis=-1)
    nchunks = b.shape[-1] // chunk
    bc = jnp.moveaxis(
        b.reshape(b.shape[:-1] + (nchunks, chunk)), -2, 0)  # [nc, ..., chunk]

    def step(member, bk):
        m = jnp.any(a[..., :, None] == bk[..., None, :], axis=-1)
        return member | m, None

    member0 = jnp.zeros(a.shape, dtype=bool)
    member, _ = jax.lax.scan(step, member0, bc)
    valid = a != sentinel
    return jnp.where(valid & member, a, sentinel)


# --------------------------------------------------------------------------
# flash_attention (reference: plain softmax attention)
# --------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None
                    ) -> jax.Array:
    """Reference attention. q: [B, Hq, Tq, d]; k, v: [B, Hkv, Tk, d].

    GQA: Hq must be a multiple of Hkv; kv heads are repeated.
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        # query i attends to keys [0, i + (tk - tq)] (decode offset aware)
        qi = jnp.arange(tq)[:, None] + (tk - tq)
        ki = jnp.arange(tk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis: x * rsqrt(mean(x^2) + eps) * gamma."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
    return out.astype(x.dtype)
