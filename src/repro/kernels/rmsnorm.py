"""Pallas TPU kernel: fused RMSNorm (row-tiled).

Cheap fused epilogue used by every LM layer: one pass computes the row mean
square and applies the scale — avoids materializing the normalized
intermediate in HBM. f32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # [bm, d]
    g = g_ref[...].astype(jnp.float32)          # [1, d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm_pallas(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
                   bm: int = 256, interpret: bool = False) -> jax.Array:
    """RMSNorm over the last axis. x: [R, d] (callers flatten batch dims)."""
    R, d = x.shape
    assert gamma.shape == (d,)
    bm = min(bm, R)
    assert R % bm == 0, f"rows {R} not a multiple of bm={bm}"
    grid = (R // bm,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, gamma[None, :])
