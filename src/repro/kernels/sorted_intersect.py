"""Pallas TPU kernel: row-wise padded-set intersection.

The INT instruction is BENU's compute hot-spot — the paper's computation-cost
model literally counts INT executions (§4.3.1). On TPU we realize a batch of
INT instructions (one frontier level) as one kernel launch over the frontier.

Design (TPU-native, not a CUDA port)
------------------------------------
Membership of each ``a`` element in the row's ``b`` set is tested with a
block-broadcast compare matrix — a dense ``[bm, D, bk]`` equality reduce that
maps onto the VPU (8x128 vector lanes); sorted-merge / binary-search variants
are serial and branchy, hostile to the TPU's SIMD model. ``D`` is padded to a
multiple of 128 so rows are lane-aligned. The ``b`` row is consumed in
``bk``-wide chunks from VMEM so the compare working set stays bounded:
``bm * D * bk`` bools. Output keeps matching ``a`` entries in place (holes =
sentinel), so results remain valid padded sets with no compaction step.

VMEM budget per block (bm=8, D=2048, bk=256, int32): a 64KiB + b 64KiB +
o 64KiB + compare 4MiB(bool) -> fits comfortably in the 16MiB VMEM of a v5e
core; tune ``bm``/``bk`` down for larger D.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(a_ref, b_ref, o_ref, *, sentinel: int, bk: int):
    a = a_ref[...]                      # [bm, D]
    d = a.shape[-1]
    nchunks = d // bk

    def body(i, member):
        bchunk = b_ref[:, pl.dslice(i * bk, bk)]          # [bm, bk]
        eq = a[:, :, None] == bchunk[:, None, :]           # [bm, D, bk]
        return member | jnp.any(eq, axis=-1)

    member = jax.lax.fori_loop(
        0, nchunks, body, jnp.zeros(a.shape, dtype=jnp.bool_))
    valid = a != sentinel
    o_ref[...] = jnp.where(valid & member, a, sentinel)


@functools.partial(jax.jit, static_argnames=("sentinel", "bm", "bk",
                                             "interpret"))
def sorted_intersect_pallas(a: jax.Array, b: jax.Array, sentinel: int,
                            bm: int = 8, bk: int = 128,
                            interpret: bool = False) -> jax.Array:
    """``a ∩ b`` per row for padded sets. a, b: int32[B, D] -> int32[B, D].

    ``D`` must be a multiple of ``bk`` and ``B`` a multiple of ``bm``
    (callers pad; see ops.intersect_padded).
    """
    B, D = a.shape
    assert b.shape == (B, D), (a.shape, b.shape)
    assert D % bk == 0, f"D={D} not a multiple of bk={bk}"
    assert B % bm == 0, f"B={B} not a multiple of bm={bm}"
    grid = (B // bm,)
    return pl.pallas_call(
        functools.partial(_intersect_kernel, sentinel=sentinel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda i: (i, 0)),
            pl.BlockSpec((bm, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), a.dtype),
        interpret=interpret,
    )(a, b)
