"""launch package."""
