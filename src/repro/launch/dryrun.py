import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production mesh and extract roofline terms from the compiled artifact.

The two lines above MUST stay the first statements of this module (jax
locks the device count at first init); do not move them below the imports.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --arch benu --shape enum_128m --multi-pod
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Per cell it records (results/dryrun/<arch>__<shape>__<mesh>.json):
    memory_analysis   bytes per device (argument/output/temp/generated)
    cost_analysis     HLO flops / bytes accessed (per-device partition)
    collective_bytes  sum of operand bytes of every all-gather / all-reduce
                      / reduce-scatter / all-to-all / collective-permute in
                      the post-SPMD optimized HLO, by op kind
    roofline          the three §Roofline terms (seconds) + dominant term
"""

import argparse
import json
import math
import re
import time
from typing import Dict, Optional

import jax

# TPU v5e hardware constants (per chip) — §Roofline
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link

def analyze_cell(arch: str, shape: str, multi_pod: bool,
                 sharding_mode: str = "fsdp") -> Dict:
    from .mesh import make_production_mesh
    from .steps import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod,
                      sharding_mode=sharding_mode)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    from ..compat import cost_analysis_dict
    mem = compiled.memory_analysis()
    # list-of-dicts on older jax; one dict on newer — normalize
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()

    # loop-aware accounting (XLA's cost_analysis counts while bodies once —
    # useless for scan-over-layers models); see hlo_analysis.py
    from .hlo_analysis import analyze as hlo_analyze
    tot = hlo_analyze(hlo)
    flops = tot.flops
    bytes_acc = tot.hbm_bytes
    coll_bytes = tot.coll_operand_total
    coll = {k: int(v) for k, v in tot.coll_operand_bytes.items()}
    coll["count"] = tot.coll_count
    coll_wire = {k: int(v) for k, v in tot.coll_wire_bytes.items()}

    # every quantity is per-chip (the compiled module is one SPMD partition)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_collective = tot.coll_wire_total / ICI_BW
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_collective)), key=lambda kv: kv[1])[0]

    meta = cell.meta
    dims = meta.get("dims", {})
    tokens = 0
    if meta["family"] == "lm":
        if meta["kind"] == "lm_train":
            tokens = dims["seq"] * dims["batch"]
        elif meta["kind"] == "lm_prefill":
            tokens = dims["seq"] * dims["batch"]
        else:
            tokens = dims["batch"]
    model_flops = 0.0
    if meta["family"] == "lm":
        mult = 6 if meta["kind"] == "lm_train" else 2
        model_flops = mult * meta["n_active_params"] * tokens
    useful_ratio = (model_flops / (flops * n_chips)
                    if flops > 0 else 0.0)

    report = {
        "arch": arch, "shape": shape,
        "mesh": ("2x16x16 pod,data,model" if multi_pod
                 else "16x16 data,model"),
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      + mem.output_size_in_bytes),
        },
        "cost_analysis": {"flops_per_chip": flops,
                          "bytes_per_chip": bytes_acc,
                          "xla_flops_loops_once": float(
                              cost.get("flops", 0.0)),
                          "xla_bytes_loops_once": float(
                              cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "collectives_wire": coll_wire,
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_collective, "dominant": dom,
            "model_flops": model_flops,
            "useful_flops_ratio": useful_ratio,
        },
        "sharding_mode": sharding_mode,
        "meta": {k: v for k, v in meta.items() if k != "plan"},
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-benu", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--sharding-mode", default="fsdp",
                    choices=["fsdp", "zero1", "fsdp2d"],
                    help="LM train-cell parameter layout (see "
                         "launch/shardings.py and EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    from ..configs import all_cells
    cells = (all_cells(include_benu=args.include_benu) if args.all
             else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        tag = "multipod" if args.multi_pod else "pod"
        name = f"{arch.replace('/', '_')}__{shape}__{tag}"
        try:
            rep = analyze_cell(arch, shape, args.multi_pod,
                               sharding_mode=args.sharding_mode)
            path = os.path.join(args.out, name + ".json")
            with open(path, "w") as f:
                json.dump(rep, f, indent=1)
            r = rep["roofline"]
            print(f"OK   {name}: compile {rep['compile_s']}s "
                  f"mem/dev {rep['memory_analysis']['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"compute {r['compute_s']*1e3:.2f}ms "
                  f"memory {r['memory_s']*1e3:.2f}ms "
                  f"coll {r['collective_s']*1e3:.2f}ms -> {r['dominant']}",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((name, str(e)[:300]))
            print(f"FAIL {name}: {str(e)[:300]}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
