"""Distributed subgraph-enumeration launcher (the paper's workload).

    PYTHONPATH=src python -m repro.launch.enumerate \
        --pattern chordal-square --n 2000 --edges 8000 [--devices 8] \
        [--engine dist|jax|ref] [--hot 64] [--rebalance] [--vcbc]

Generates a synthetic graph, compiles the best execution plan (Alg. 3 with
all optimizations), and runs the chosen engine through the unified
Executor API (core/executor.py) over every device, reporting counts + the
paper's cost metrics (DBQ rows crossed / computation per shard / skew).
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="chordal-square")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--edges", type=int, default=8000)
    ap.add_argument("--graph", choices=["er", "powerlaw"],
                    default="powerlaw")
    ap.add_argument("--engine", choices=["dist", "jax", "ref"],
                    default="dist")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--batch-per-shard", type=int, default=256)
    ap.add_argument("--hot", type=int, default=64)
    ap.add_argument("--rebalance", action="store_true")
    ap.add_argument("--vcbc", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    from ..core.executor import make_executor
    from ..core.pattern import get_pattern
    from ..core.plangen import generate_best_plan
    from ..graph.generate import erdos_renyi, powerlaw

    P = get_pattern(args.pattern)
    g = (powerlaw(args.n, max(args.edges // args.n, 2), seed=args.seed)
         if args.graph == "powerlaw"
         else erdos_renyi(args.n, args.edges, seed=args.seed))
    plan = generate_best_plan(P, g.stats(), vcbc=args.vcbc)
    print(plan.pretty())

    if args.engine == "dist":
        ex = make_executor("dist", hot=args.hot, rebalance=args.rebalance)
        batch = args.batch_per_shard * len(jax.devices())
    else:
        ex = make_executor(args.engine)
        batch = args.batch_per_shard
    t0 = time.time()
    st = ex.run(plan, g, batch=batch)
    dt = time.time() - t0
    print(f"\nengine             : {args.engine}")
    print(f"matches            : {st.count}")
    print(f"wall time          : {dt:.2f}s")
    print(f"chunks run         : {st.chunks_run} "
          f"(split {st.chunks_split}, retried {st.chunks_retried})")
    if args.engine == "dist":
        cold = st.extras["cold_rows_fetched"]
        print(f"cold rows fetched  : {cold} "
              f"(x {plan.n * 4}B row bytes = {cold * 512 / 1e6:.1f}MB class)")
        print(f"per-shard matches  : "
              f"{st.extras['per_shard_counts'].tolist()}")
    elif args.engine == "ref":
        print(f"remote DBQ rows    : {st.extras['remote_queries']}")


if __name__ == "__main__":
    main()
