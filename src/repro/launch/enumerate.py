"""Distributed subgraph-enumeration launcher (the paper's workload).

    PYTHONPATH=src python -m repro.launch.enumerate \
        --pattern chordal-square --n 2000 --edges 8000 [--devices 8] \
        [--engine dist|jax|jax-gpu|ref|oocache] [--hot 64] [--rebalance] \
        [--vcbc]

``--engine jax-gpu`` runs the accelerator fetch path: single-use DBQ
gathers fuse into the intersect kernel (kernels/gather_intersect.py, see
docs/KERNELS.md) so gathered row blocks never round-trip through HBM; on
this CPU container pass ``--gather-intersect-impl interpret`` to run the
Pallas kernel in interpret mode (otherwise it falls back to the unfused
reference, still exact).

``--engine oocache`` runs the out-of-core fetch path: adjacency rows live
in host-RAM shards, device memory holds only a bounded row cache
(``--cache-frac`` of N rows + ``--hot`` pinned top-degree rows) and the
next chunk's rows are prefetched while the current chunk computes; the
report adds hit rate / cold rows / bytes moved per DBQ level.

Generates a synthetic graph, compiles the best execution plan (Alg. 3 with
all optimizations), and runs the chosen engine through the unified
Executor API (core/executor.py) over every device, reporting counts + the
paper's cost metrics (DBQ rows crossed / computation per shard / skew).

Continuous enumeration (S-BENU, Alg. 4) runs the timestep loop instead:

    PYTHONPATH=src python -m repro.launch.enumerate \
        --engine sbenu-jax --pattern "q1'" --n 5000 --edges 25000 \
        --steps 3 --update-batch 500

``--engine sbenu`` interprets every task; ``--engine sbenu-jax`` runs the
vectorized delta-frontier engine over the six-block device snapshot;
``--engine sbenu-dist`` shards the six blocks over every device
(``--devices N`` forces an N-way host mesh) with typed DBQs served by
request/response all_to_all — ``--hot`` rows replicated, ``--rebalance``
striping every delta frontier round-robin across the mesh.
"""

from __future__ import annotations

import argparse
import os
import time


def _run_continuous(args) -> None:
    """Algorithm 4's timestep loop over the chosen S-BENU backend."""
    from ..core.estimate import GraphStats
    from ..core.pattern import get_pattern
    from ..core.sbenu import generate_best_sbenu_plans, run_timestep
    from ..graph.dynamic import SnapshotStore, stream_width_floors
    from ..graph.generate import edge_stream

    P = get_pattern(args.pattern)
    if not P.directed:
        raise SystemExit(f"--engine {args.engine} needs a directed pattern "
                         f"(q1'..q5', dtoy); got {args.pattern!r}")
    g0, batches = edge_stream(n=args.n, m_init=args.edges, steps=args.steps,
                              batch=args.update_batch, seed=args.seed)
    store = SnapshotStore(g0)
    stats = GraphStats(args.n, args.edges, delta_edges=args.update_batch)
    plans = generate_best_sbenu_plans(P, stats)
    print(f"pattern {args.pattern}: {len(plans)} incremental plans "
          f"(one per delta edge)")
    backend = None
    if args.engine == "sbenu-jax":
        # one backend for the whole stream, widths pinned over every step:
        # the JIT engine compiles once instead of retracing per step
        from ..core.executor import SBenuJaxBackend
        d, dd = stream_width_floors(g0, batches)
        backend = SBenuJaxBackend(collect="counts", d_min=d,
                                  delta_d_min=dd,
                                  snapshot_storage=args.snapshot_storage)
    elif args.engine == "sbenu-dist":
        from ..core.executor import SBenuDistBackend
        d, dd = stream_width_floors(g0, batches)
        backend = SBenuDistBackend(collect="counts", d_min=d,
                                   delta_d_min=dd, hot=args.hot,
                                   rebalance=args.rebalance)
    total_p = total_m = 0
    t_all = 0.0
    for step, batch in enumerate(batches, 1):
        t0 = time.time()
        dp, dm, ctr = run_timestep(P, plans, store, batch,
                                   engine=args.engine, backend=backend,
                                   chunk=args.batch_per_shard,
                                   collect="counts")
        dt = time.time() - t0
        t_all += dt
        total_p += ctr.matches_plus
        total_m += ctr.matches_minus
        print(f"step {step}: dR+ {ctr.matches_plus:>8}  "
              f"dR- {ctr.matches_minus:>8}  {dt:6.2f}s  "
              f"{args.update_batch / max(dt, 1e-9):,.0f} updates/s")
    print(f"\nengine             : {args.engine}")
    print(f"total dR+ / dR-    : {total_p} / {total_m}")
    print(f"wall time          : {t_all:.2f}s over {args.steps} steps")
    if args.engine == "sbenu-dist":
        import jax
        print(f"mesh               : {len(jax.devices())} devices "
              f"(hot {args.hot} rows replicated, "
              f"rebalance {'on' if args.rebalance else 'off'})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="chordal-square")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--edges", type=int, default=8000)
    ap.add_argument("--graph", choices=["er", "powerlaw"],
                    default="powerlaw")
    ap.add_argument("--engine",
                    choices=["dist", "jax", "jax-gpu", "ref", "oocache",
                             "sbenu", "sbenu-jax", "sbenu-dist"],
                    default="dist")
    ap.add_argument("--gather-intersect-impl", default="auto",
                    help="jax-gpu: fused kernel impl (auto | pallas | "
                         "interpret | ref/chunked/binary fallbacks); "
                         "'interpret' runs the Pallas kernel on CPU")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--batch-per-shard", type=int, default=256)
    ap.add_argument("--hot", type=int, default=64,
                    help="replicated/pinned hot rows: top-degree for "
                         "dist/oocache (degree-relabeled load); the "
                         "highest-id range for sbenu-dist (streams are "
                         "not relabeled)")
    ap.add_argument("--cache-frac", type=float, default=0.15,
                    help="oocache: device LRU slab size as a fraction of N")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="oocache: disable the async next-chunk prefetch")
    ap.add_argument("--snapshot-storage", choices=["device", "host"],
                    default="device",
                    help="sbenu-jax: 'host' keeps resident blocks in "
                         "host-RAM shards (zero persistent HBM between "
                         "steps; per-step compute still transfers full "
                         "blocks — slower compat path until the OOC "
                         "delta-frontier engine lands)")
    ap.add_argument("--rebalance", action="store_true")
    ap.add_argument("--vcbc", action="store_true")
    ap.add_argument("--steps", type=int, default=3,
                    help="time steps (continuous engines)")
    ap.add_argument("--update-batch", type=int, default=200,
                    help="edge updates per time step (continuous engines)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    if args.engine in ("sbenu", "sbenu-jax", "sbenu-dist"):
        _run_continuous(args)
        return

    import jax

    from ..core.executor import make_executor
    from ..core.pattern import get_pattern
    from ..core.plangen import generate_best_plan
    from ..graph.generate import erdos_renyi, powerlaw

    P = get_pattern(args.pattern)
    g = (powerlaw(args.n, max(args.edges // args.n, 2), seed=args.seed)
         if args.graph == "powerlaw"
         else erdos_renyi(args.n, args.edges, seed=args.seed))
    plan = generate_best_plan(P, g.stats(), vcbc=args.vcbc)
    print(plan.pretty())

    if args.engine == "dist":
        ex = make_executor("dist", hot=args.hot, rebalance=args.rebalance)
        batch = args.batch_per_shard * len(jax.devices())
    elif args.engine == "oocache":
        ex = make_executor("oocache", cache_frac=args.cache_frac,
                           hot=args.hot, prefetch=not args.no_prefetch)
        batch = args.batch_per_shard
    elif args.engine == "jax-gpu":
        ex = make_executor("jax-gpu",
                           gather_intersect_impl=args.gather_intersect_impl)
        batch = args.batch_per_shard
    else:
        ex = make_executor(args.engine)
        batch = args.batch_per_shard
    t0 = time.time()
    st = ex.run(plan, g, batch=batch)
    dt = time.time() - t0
    print(f"\nengine             : {args.engine}")
    print(f"matches            : {st.count}")
    print(f"wall time          : {dt:.2f}s")
    print(f"chunks run         : {st.chunks_run} "
          f"(split {st.chunks_split}, retried {st.chunks_retried})")
    if args.engine == "dist":
        cold = st.extras["cold_rows_fetched"]
        print(f"cold rows fetched  : {cold} "
              f"(x {plan.n * 4}B row bytes = {cold * 512 / 1e6:.1f}MB class)")
        print(f"per-shard matches  : "
              f"{st.extras['per_shard_counts'].tolist()}")
    elif args.engine == "oocache":
        c = st.extras["cache"]
        print(f"host store         : {st.extras['host_store_bytes'] / 1e6:.1f}MB "
              f"in {st.extras['host_store_shards']} shards")
        print(f"device resident    : {st.extras['device_resident_rows']} rows "
              f"({st.extras['device_resident_bytes'] / 1e6:.2f}MB = "
              f"{st.extras['device_resident_rows'] / (g.n + 1) * 100:.1f}% of N)")
        print(f"row queries        : {c['queries']} ({c['hit_rate'] * 100:.1f}% "
              f"served without a host fetch)")
        print(f"cold rows fetched  : {c['cold_rows']} "
              f"({c['bytes_demand'] / 1e6:.2f}MB demand + "
              f"{c['bytes_prefetch'] / 1e6:.2f}MB prefetch)")
        print(f"prefetch used      : {c['prefetch_used']} rows; "
              f"evictions {c['evictions']}")
        for lvl, (q, cold, b) in c["per_level"].items():
            print(f"  DBQ level {lvl}      : {q:>9} queries  {cold:>8} cold  "
                  f"{b / 1e6:8.2f}MB")
    elif args.engine == "ref":
        print(f"remote DBQ rows    : {st.extras['remote_queries']}")
    elif args.engine in ("jax", "jax-gpu"):
        lv = st.extras["level_sizes"]
        print(f"fused fetch        : "
              f"{'on' if st.extras['fused_fetch'] else 'off'}")
        print(f"frontier rows/level: {lv.tolist()}")


if __name__ == "__main__":
    main()
