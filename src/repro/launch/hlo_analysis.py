"""Loop-aware roofline accounting from post-SPMD optimized HLO text.

Why not ``compiled.cost_analysis()``: XLA's HLO cost analysis counts every
``while`` body ONCE, but our models run layers (and blockwise-attention
chunks) under ``lax.scan`` — a 32-layer model would be undercounted ~32x.
This module re-derives the three roofline inputs from ``compiled.as_text()``
with while-loop trip-count multiplication:

    flops             2 * result_elems * contracted_elems per `dot`
                      (dots dominate; elementwise flops are ignored — they
                      are bandwidth-, not FLOP-limited)
    hbm_bytes         sum over top-level instructions of operand + result
                      buffer bytes (fusion-internal instructions excluded:
                      a fusion reads its operands and writes its output
                      once). This approximates HBM traffic the way XLA's
                      own bytes-accessed does, loop-aware.
    collectives       per-kind operand bytes and ring-model wire bytes,
                      with group sizes parsed from replica_groups

Trip counts come from the integer bound in each while's condition
computation (lax.scan lowers to a counted loop; the bound is a literal).

Everything is per-chip: the compiled module is one SPMD partition.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota"}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*{\s*$")


def _shape_info(type_str: str) -> Tuple[int, List[int]]:
    """(bytes, dims) for one 'f32[4,8]{...}' type; tuples summed."""
    total = 0
    dims_last: List[int] = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for x in d:
            n *= x
        total += _DTYPE_BYTES[dt] * n
        dims_last = d
    return total, dims_last


@dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_dims: List[int]
    line: str


@dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr] = field(default_factory=dict)
    lines: List[str] = field(default_factory=list)
    is_entry: bool = False


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and ("(" in line):
                m = _COMP_HEAD_RE.match(line.strip())
                if m:
                    cur = Computation(name=m.group(1),
                                      is_entry=line.startswith("ENTRY"))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
        im = _INSTR_RE.match(line)
        if im:
            nbytes, dims = _shape_info(im.group(2))
            cur.instrs[im.group(1)] = Instr(
                name=im.group(1), op=im.group(3), result_bytes=nbytes,
                result_dims=dims, line=line.strip())
    return comps


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:  # explicit groups: {{0,1,2,...}, ...}
        return len(m.group(1).split(","))
    return default


def _trip_count(cond: Computation) -> int:
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_operand_bytes: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _COLL_KINDS})
    coll_wire_bytes: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _COLL_KINDS})
    coll_count: int = 0

    def scaled(self, k: float) -> "Totals":
        return Totals(
            flops=self.flops * k, hbm_bytes=self.hbm_bytes * k,
            coll_operand_bytes={a: b * k for a, b
                                in self.coll_operand_bytes.items()},
            coll_wire_bytes={a: b * k for a, b
                             in self.coll_wire_bytes.items()},
            coll_count=int(self.coll_count * k))

    def add(self, o: "Totals") -> None:
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        for k in _COLL_KINDS:
            self.coll_operand_bytes[k] += o.coll_operand_bytes[k]
            self.coll_wire_bytes[k] += o.coll_wire_bytes[k]
        self.coll_count += o.coll_count

    @property
    def coll_operand_total(self) -> float:
        return sum(self.coll_operand_bytes.values())

    @property
    def coll_wire_total(self) -> float:
        return sum(self.coll_wire_bytes.values())


def _dot_flops(ins: Instr, comp: Computation,
               universe: Dict[str, Instr]) -> float:
    out_elems = 1
    for d in ins.result_dims:
        out_elems *= d
    m = re.search(r"dot\(%([\w.\-]+),", ins.line)
    lhs = comp.instrs.get(m.group(1)) if m else None
    if lhs is None and m:
        lhs = universe.get(m.group(1))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contracted = 1
    if lhs is not None and cm and cm.group(1):
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(lhs.result_dims):
                contracted *= lhs.result_dims[ci]
    return 2.0 * out_elems * contracted


def analyze(text: str) -> Totals:
    comps = parse_computations(text)
    universe: Dict[str, Instr] = {}
    for c in comps.values():
        universe.update(c.instrs)
    fusion_comps = set()
    for c in comps.values():
        for ins in c.instrs.values():
            fm = re.search(r"calls=%([\w.\-]+)", ins.line)
            if fm:
                fusion_comps.add(fm.group(1))
    cache: Dict[str, Totals] = {}

    def comp_totals(name: str) -> Totals:
        if name in cache:
            return cache[name]
        cache[name] = Totals()          # cycle guard
        c = comps.get(name)
        if c is None:
            return cache[name]
        t = Totals()
        for ins in c.instrs.values():
            if ins.op == "while":
                wm = re.search(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)",
                               ins.line)
                if wm:
                    trips = _trip_count(comps[wm.group(1)]) \
                        if wm.group(1) in comps else 1
                    t.add(comp_totals(wm.group(2)).scaled(max(trips, 1)))
                # the while's own buffer traffic is once per iteration and
                # already approximated inside the body accounting
                continue
            if ins.op in ("call", "conditional"):
                for cm2 in re.finditer(r"%([\w.\-]+)", ins.line):
                    if cm2.group(1) in comps and cm2.group(1) != ins.name \
                            and cm2.group(1) in fusion_comps:
                        pass
                cm3 = re.search(r"to_apply=%([\w.\-]+)", ins.line)
                if cm3:
                    t.add(comp_totals(cm3.group(1)))
            if ins.op == "fusion":
                # fused dots still count FLOPs: scan the fusion body
                fm = re.search(r"calls=%([\w.\-]+)", ins.line)
                if fm and fm.group(1) in comps:
                    for fin in comps[fm.group(1)].instrs.values():
                        if fin.op == "dot":
                            t.flops += _dot_flops(
                                fin, comps[fm.group(1)], universe)
            if ins.op == "dot":
                t.flops += _dot_flops(ins, c, universe)
            if ins.op in _COLL_KINDS or \
                    any(ins.op == k + "-start" for k in _COLL_KINDS):
                kind = ins.op.replace("-start", "")
                g = max(_group_size(ins.line), 1)
                r = ins.result_bytes
                if kind == "all-gather":
                    operand = r / g
                    wire = operand * (g - 1)
                elif kind == "reduce-scatter":
                    operand = r * g
                    wire = r * (g - 1)
                elif kind == "all-reduce":
                    operand = r
                    wire = 2.0 * r * (g - 1) / g
                elif kind == "all-to-all":
                    operand = r
                    wire = r * (g - 1) / g
                else:                     # collective-permute
                    operand = r
                    wire = r
                t.coll_operand_bytes[kind] += operand
                t.coll_wire_bytes[kind] += wire
                t.coll_count += 1
            # HBM proxy: reads (known operand buffers) + write (result)
            if ins.op not in _FREE_OPS and not ins.op.endswith("-done"):
                if ins.op in ("dynamic-slice", "gather", "slice"):
                    # touches a result-sized window, not the whole operand
                    t.hbm_bytes += 2 * ins.result_bytes
                    continue
                reads = 0
                op_sizes = []
                for om in re.finditer(r"%([\w.\-]+)",
                                      ins.line.split("=", 1)[-1]):
                    src = c.instrs.get(om.group(1))
                    if src is not None and src.name != ins.name \
                            and src.op != "constant":
                        op_sizes.append(src.result_bytes)
                if ins.op in ("dynamic-update-slice", "scatter") \
                        and op_sizes:
                    # in-place window update: traffic ~ 2x the update size
                    t.hbm_bytes += 2 * min(op_sizes)
                    continue
                t.hbm_bytes += sum(op_sizes) + ins.result_bytes
        cache[name] = t
        return t

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return Totals()
    # entry totals, with fusion computations excluded from direct scan
    return comp_totals(entry)
