"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is the outer pure-DP axis (DCN between pods; gradients all-reduce
over it, parameters stay replicated pod-to-pod).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run forces 512 host devices before first jax init; smoke
tests run with the default single device).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devs)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this)")
    # more devices than the mesh (e.g. 512 forced, single-pod 256 wanted)
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def flat_axes(multi_pod: bool):
    """All mesh axes flattened (edge-sharding, candidate-sharding, BENU)."""
    return ("pod", "data", "model") if multi_pod else ("data", "model")
