"""Serving launcher: batched decode with a KV cache (LM) or batched CTR
scoring (BST).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 16 --decode-steps 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    from ..configs import get_config
    spec = get_config(args.arch)
    if args.smoke:
        spec = spec.smoke()
    cfg = spec.model_cfg

    if spec.family == "recsys":
        from ..data.pipelines import RecsysStream
        from ..models.bst import bst_serve, init_bst_params
        params = init_bst_params(jax.random.PRNGKey(0), cfg)
        stream = RecsysStream(cfg.n_items, cfg.n_user_feats, cfg.seq_len,
                              cfg.user_feat_len, args.batch)
        serve = jax.jit(lambda p, b: bst_serve(p, b, cfg))
        t0 = time.time()
        for i in range(args.decode_steps):
            scores = serve(params, {k: jnp.asarray(v)
                                    for k, v in stream.batch(i).items()})
        scores.block_until_ready()
        dt = time.time() - t0
        print(f"{args.decode_steps} batches of {args.batch}: {dt:.2f}s "
              f"({args.decode_steps * args.batch / dt:.0f} req/s); "
              f"mean CTR {float(scores.mean()):.3f}")
        return

    from ..models.transformer import (decode_step, forward, init_caches,
                                      init_params)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, pl = args.batch, args.prompt_len
    caches = init_caches(cfg, b, args.cache_len)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, pl)), jnp.int32)

    # prefill token-by-token through the decode path (exercises the cache)
    dstep = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(pl):
        logits, caches = dstep(params, caches, prompt[:, i:i + 1],
                               jnp.asarray(i, jnp.int32))
    generated = []
    for i in range(args.decode_steps):
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(tok)[:, 0])
        logits, caches = dstep(params, caches, tok,
                               jnp.asarray(pl + i, jnp.int32))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    toks = b * (pl + args.decode_steps)
    print(f"prefill {pl} + decode {args.decode_steps} x batch {b}: "
          f"{dt:.2f}s ({toks / dt:.0f} tok/s)")
    print("sample:", np.stack(generated, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
