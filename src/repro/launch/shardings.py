"""PartitionSpec assignment for every parameter / cache / batch pytree.

LM parameter rules (FSDP over "data", TP/EP over "model"):

    embed [V, D]                  (model, data)     vocab x fsdp
    lm_head [D, V]                (data, model)
    wq/wk/wv [L, D, HD]           (-, data, model)  fsdp x TP(flattened heads)
    wo [L, HD, D]                 (-, model, data)
    biases [L, HD]                (-, model)
    swiglu gate/up [L, D, F]      (-, data, model)
    swiglu down [L, F, D]         (-, model, data)
    MLA wkv_a [L, D, r+rope]      (-, data, -)
    MLA wkv_b [L, r, H(n+v)]      (-, -, model)
    MoE router [L, D, E]          (-, data, -)
    MoE gate/up [L, E, D, F]      (-, model, data, -)   EP over model
    MoE down [L, E, F, D]         (-, model, -, data)
    norms                         replicated

Optimizer state (m, v) inherits the parameter spec leaf-for-leaf (FSDP: opt
state shards with its parameter). KV caches shard the *sequence* axis over
"model" (decode_32k) or over every axis (long_500k) — the softmax over the
sharded axis compiles to partial-max/sum + all-reduce, i.e. flash-decode.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes, flat_axes

DATA, MODEL = "data", "model"


def _key_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return tuple(names)


def lm_param_spec_one(names: Tuple[str, ...], ndim: int) -> P:
    leaf = names[-1] if names else ""
    in_stack = any(n.endswith("_layers") for n in names)
    lead = (None,) if in_stack else ()
    if leaf == "embed":
        return P(MODEL, DATA)
    if leaf == "lm_head":
        return P(DATA, MODEL)
    if leaf in ("final_norm",):
        return P(None)
    if leaf in ("wq", "wk", "wv", "w_gate", "w_up"):
        if ndim == len(lead) + 3:                   # MoE expert [L, E, D, F]
            return P(*lead, MODEL, DATA, None)
        return P(*lead, DATA, MODEL)
    if leaf in ("wo", "w_down"):
        if ndim == len(lead) + 3:                   # [L, E, F, D]
            return P(*lead, MODEL, None, DATA)
        return P(*lead, MODEL, DATA)
    if leaf in ("bq", "bk", "bv"):
        return P(*lead, MODEL)
    if leaf == "wkv_a":
        return P(*lead, DATA, None)
    if leaf == "wkv_b":
        return P(*lead, None, MODEL)
    if leaf == "router":
        return P(*lead, DATA, None)
    # norms / scalars / anything else: replicated
    return P(*([None] * ndim))


def lm_param_specs(shapes: Any) -> Any:
    """Pytree of PartitionSpec matching a params pytree (from eval_shape)."""
    def assign(path, leaf):
        return lm_param_spec_one(_key_names(path), leaf.ndim)

    return jax.tree_util.tree_map_with_path(assign, shapes)


def opt_state_specs(param_specs: Any) -> Any:
    """AdamWState(step, m, v) mirroring the param specs."""
    from ..train.optimizer import AdamWState
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def fsdp2d_param_specs(shapes: Any, mesh: Mesh,
                       multi_pod: bool = False) -> Any:
    """Pure 2D FSDP: every parameter sharded over the FLATTENED
    ("data","model") axes on its largest divisible non-stack dim; no tensor
    parallelism anywhere.

    Rationale (phi4 train_4k hillclimb, EXPERIMENTS.md §Perf): with TP the
    forward/backward insert ~3 activation all-reduces of [B/dev, T, D] per
    layer per microbatch over the model axis — at 4k tokens/chip those
    dwarf the parameter traffic. 2D FSDP removes activation collectives
    entirely; parameters are re-gathered per pass, which is cheap for
    <=4B-param models (napkin in EXPERIMENTS.md)."""
    flat = flat_axes(multi_pod)[1:] if multi_pod else flat_axes(False)
    # exclude "pod": parameters replicated across pods (DCN)
    size = 1
    for a in flat:
        size *= mesh.shape[a]

    def assign(path, leaf):
        names = _key_names(path)
        in_stack = any(n.endswith("_layers") for n in names)
        start = 1 if in_stack and leaf.ndim > 1 else 0
        best, best_dim = None, -1
        for i in range(start, leaf.ndim):
            if leaf.shape[i] % size == 0 and leaf.shape[i] > best_dim:
                best, best_dim = i, leaf.shape[i]
        entries = [None] * leaf.ndim
        if best is not None:
            entries[best] = flat
        return P(*entries)

    return jax.tree_util.tree_map_with_path(assign, shapes)


def zero1_param_specs(shapes: Any) -> Any:
    """ZeRO-1 layout: parameters sharded over "model" only (replicated
    across "data"), optimizer state additionally sharded over "data".

    vs FSDP: the per-layer-per-microbatch parameter all-gathers disappear;
    the compiler derives exactly one grads reduce(-scatter) + one updated-
    param all-gather per step from the spec difference between params
    (data-replicated) and opt state (data-sharded). Wire cost becomes
    O(params) per step instead of O(params x passes x microbatches).
    """
    def assign(path, leaf):
        names = _key_names(path)
        spec = lm_param_spec_one(names, leaf.ndim)
        entries = [None if ax == DATA else ax for ax in spec] \
            + [None] * (leaf.ndim - len(spec))
        return P(*entries)

    return jax.tree_util.tree_map_with_path(assign, shapes)


def zero1_opt_specs(param_specs: Any, shapes: Any, mesh: Mesh) -> Any:
    """Opt-state specs: param spec + "data" added on the first free,
    divisible dimension (the ZeRO-1 shard axis)."""
    from ..train.optimizer import AdamWState

    def assign(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        dsize = mesh.shape[DATA]
        for i, ax in enumerate(entries):
            if ax is None and leaf.shape[i] % dsize == 0 \
                    and leaf.shape[i] >= dsize:
                entries[i] = DATA
                break
        return P(*entries)

    mv = jax.tree.map(assign, param_specs, shapes,
                      is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), m=mv, v=mv)


def cache_specs(shapes: Any, multi_pod: bool, long_context: bool) -> Any:
    """KV-cache specs. GQA leaves: k/v [nl, B, S, KV, dh]; MLA: c_kv
    [nl, B, S, r], k_rope [nl, B, S, rope]; length [nl]."""
    seq_axes = flat_axes(multi_pod) if long_context else MODEL
    dp = dp_axes(multi_pod) if not long_context else None

    def assign(path, leaf):
        names = _key_names(path)
        leafname = names[-1]
        if leafname in ("k", "v"):
            return P(None, dp, seq_axes, None, None)
        if leafname == "c_kv" or leafname == "k_rope":
            return P(None, dp, seq_axes, None)
        return P(*([None] * leaf.ndim))             # lengths

    return jax.tree_util.tree_map_with_path(assign, shapes)


def gnn_param_specs(shapes: Any) -> Any:
    """GNN models are small: replicate every leaf."""
    return jax.tree.map(lambda l: P(*([None] * l.ndim)), shapes)


def bst_param_specs(shapes: Any) -> Any:
    def assign(path, leaf):
        names = _key_names(path)
        leafname = names[-1] if names else ""
        if leafname in ("item_emb", "user_emb"):
            return P(MODEL, None)                   # row-sharded tables
        if leafname == "w0" and "mlp" in names:
            return P(None, MODEL)                   # widest MLP matmul
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(assign, shapes)


def batch_specs(family: str, kind: str, specs: Dict[str, Any],
                multi_pod: bool) -> Dict[str, P]:
    dp = dp_axes(multi_pod)
    flat = flat_axes(multi_pod)
    out: Dict[str, P] = {}
    if family == "lm":
        for k, v in specs.items():
            if kind == "lm_long_decode":
                out[k] = P(*([None] * v.ndim))      # batch=1
            else:
                out[k] = P(dp, *([None] * (v.ndim - 1)))
        return out
    if family == "gnn":
        for k, v in specs.items():
            if k in ("edge_src", "edge_dst", "edge_attr"):
                out[k] = P(flat, *([None] * (v.ndim - 1)))
            else:
                out[k] = P(*([None] * v.ndim))      # node tensors replicated
        return out
    if family == "recsys":
        for k, v in specs.items():
            if kind == "rec_retrieval":
                out[k] = (P(flat) if k == "cand_ids"
                          else P(*([None] * v.ndim)))
            else:
                out[k] = P(dp, *([None] * (v.ndim - 1)))
        return out
    if family == "benu":
        shard = flat
        if kind == "sbenu_enum":
            # snapshot blocks replicated, start batch sharded over the mesh
            return {k: (P(shard) if v.ndim == 1
                        else P(*([None] * v.ndim)))
                    for k, v in specs.items()}
        if kind == "sbenu_dist_enum":
            return sbenu_snapshot_specs(shard)
        return {"shards": P(shard, None, None),
                "hot_rows": P(None, None),
                "starts": P(shard), "starts_valid": P(shard)}
    raise KeyError(family)


def sbenu_snapshot_specs(axis="shard") -> Dict[str, P]:
    """PartitionSpecs for the mesh-sharded six-block streaming snapshot —
    the layout ``ShardedDeviceSnapshotStore`` (graph/dynamic.py) places
    and ``build_sbenu_dist_step`` consumes, spelled as specs.

    Value blocks (``prev_/cur_{out,in}``, the joint ``delta`` blocks) are
    row-block partitioned over the enumeration axis; the ``hot_*`` slices
    (highest-id rows + sentinel) are replicated on every device, exactly
    mirroring ``DistBackend``'s static ``shards``/``hot_rows`` split.
    ``axis`` may be one mesh axis name or a tuple of axes to flatten.
    """
    blocks = ("prev_out", "cur_out", "prev_in", "cur_in",
              "delta_joint_out", "delta_joint_in")
    specs = {name: P(axis, None) for name in blocks}
    specs.update({f"hot_{name}": P(None, None) for name in blocks})
    specs.update(starts=P(axis), starts_valid=P(axis))
    return specs


def sanitize(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    """Drop axis assignments whose mesh size does not divide the dim.

    jit ``in_shardings`` require exact divisibility (unlike internal
    with_sharding_constraint, which GSPMD pads). Example: granite's vocab
    49155 is not divisible by 16 — its embed falls back from
    (model, data) to (None, data). MoE stacks whose expert count does not
    divide the model axis fall back to sharding the FFN dim instead
    (handled here generically by trying a rotated assignment)."""
    def size(axis) -> int:
        if axis is None:
            return 1
        axes = (axis,) if isinstance(axis, str) else axis
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def fix(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        dropped = []
        for i, ax in enumerate(entries):
            if ax is not None and leaf.shape[i] % size(ax) != 0:
                dropped.append(ax)
                entries[i] = None
        # try to re-home dropped axes on a dividing, unassigned dim
        for ax in dropped:
            for i, cur in enumerate(entries):
                if cur is None and leaf.shape[i] % size(ax) == 0 \
                        and leaf.shape[i] >= size(ax) and leaf.shape[i] > 1:
                    taken = [e for e in entries if e is not None]
                    flat_taken = set()
                    for t in taken:
                        flat_taken.update((t,) if isinstance(t, str) else t)
                    axes = (ax,) if isinstance(ax, str) else ax
                    if flat_taken & set(axes):
                        continue
                    entries[i] = ax
                    break
        return P(*entries)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, spec_tree: Any, shape_tree: Any = None) -> Any:
    if shape_tree is not None:
        spec_tree = sanitize(spec_tree, shape_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
