"""Step builders: one (jit-able fn, arg specs, shardings) per dry-run cell.

``build_cell(arch, shape, mesh, multi_pod)`` returns a :class:`CellProgram`
with everything the dry-run needs: the step function, ShapeDtypeStruct
stand-ins for every argument, and the in/out shardings. The same builders
power the real train/serve launchers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..configs.base import ArchSpec
from ..layers.common import ShardCtx
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update
from .mesh import dp_axes, flat_axes
from .shardings import (batch_specs, bst_param_specs, cache_specs,
                        gnn_param_specs, lm_param_specs, named,
                        opt_state_specs, zero1_opt_specs,
                        zero1_param_specs)


@dataclass
class CellProgram:
    name: str
    fn: Callable
    args: Tuple[Any, ...]              # ShapeDtypeStructs (pytrees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: Dict[str, Any]

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.meta.get("donate", ()))

    def lower(self):
        return self.jitted().lower(*self.args)


def _eval_params(init_fn) -> Any:
    return jax.eval_shape(init_fn, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------


def _lm_cell(spec: ArchSpec, shape: str, mesh: Mesh,
             multi_pod: bool, sharding_mode: str = "fsdp") -> CellProgram:
    from ..models.transformer import (decode_step, init_caches, init_params,
                                      loss_fn, prefill_step)
    cfg = spec.model_cfg
    sp = spec.shapes[shape]
    is_train = sp.kind == "lm_train"
    if sharding_mode == "fsdp2d" and is_train:
        # no TP: batch over every axis, params 2D-sharded
        ctx = ShardCtx(mesh=mesh, dp=flat_axes(multi_pod), tp=None)
    else:
        ctx = ShardCtx(mesh=mesh, dp=dp_axes(multi_pod), tp="model")
    pshapes = _eval_params(functools.partial(init_params, cfg=cfg))
    if sharding_mode == "zero1" and is_train:
        pspecs = zero1_param_specs(pshapes)
    elif sharding_mode == "fsdp2d" and is_train:
        from .shardings import fsdp2d_param_specs
        pspecs = fsdp2d_param_specs(pshapes, mesh, multi_pod)
    else:
        pspecs = lm_param_specs(pshapes)
    psh = named(mesh, pspecs, pshapes)
    ispecs = spec.input_specs(shape)
    if sharding_mode == "fsdp2d" and is_train:
        fa = flat_axes(multi_pod)
        bspec = {k: P(fa, *([None] * (v.ndim - 1)))
                 for k, v in ispecs.items()}
    else:
        bspec = batch_specs("lm", sp.kind, ispecs, multi_pod)
    bsh = named(mesh, bspec, ispecs)
    meta = {"family": "lm", "kind": sp.kind,
            "n_params": cfg.n_params, "n_active_params": cfg.n_active_params,
            "dims": dict(sp.dims)}

    if sp.kind == "lm_train":
        opt_cfg = AdamWConfig()
        oshapes = jax.eval_shape(adamw_init, pshapes)
        if sharding_mode == "zero1":
            ospecs = zero1_opt_specs(pspecs, pshapes, mesh)
        else:
            ospecs = opt_state_specs(pspecs)
        osh = named(mesh, ospecs, oshapes)
        meta["sharding_mode"] = sharding_mode
        # gradient-accumulation microbatching: activation working set
        # scales 1/m while keeping the global batch (grads accumulate in
        # the sharded f32 grad buffer). m is capped so the per-microbatch
        # batch still shards over every DP axis (a smaller slice would
        # force XLA to replicate compute — measured 3.8x FLOP inflation,
        # EXPERIMENTS.md §Perf).
        dp_size = ctx.dp_size
        mb = int(sp.dims.get("microbatches", 4))
        mb = max(1, min(mb, sp.dims["batch"] // max(dp_size, 1)))
        meta["microbatches"] = mb

        def train_step(params, opt_state, batch):
            b = batch["tokens"].shape[0]
            mbatch = {k: v.reshape((mb, b // mb) + v.shape[1:])
                      for k, v in batch.items()}

            def one(carry, mbt):
                gsum, lsum = carry
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(p, mbt, cfg, ctx),
                    has_aux=True)(params)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(one, (g0, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            new_p, new_o, om = adamw_update(opt_cfg, grads, opt_state,
                                            params)
            metrics = dict(om)
            metrics["loss"] = lsum / mb
            return new_p, new_o, metrics

        return CellProgram(
            name=f"{spec.name}:{shape}", fn=train_step,
            args=(pshapes, oshapes, ispecs),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None), meta=meta)

    if sp.kind == "lm_prefill":
        def step(params, batch):
            return prefill_step(params, batch["tokens"], cfg, ctx)

        return CellProgram(
            name=f"{spec.name}:{shape}", fn=step,
            args=(pshapes, ispecs), in_shardings=(psh, bsh),
            out_shardings=None, meta=meta)

    # decode (decode_32k / long_500k)
    long_ctx = sp.kind == "lm_long_decode"
    b, s_max = sp.dims["batch"], sp.dims["seq"]
    cshapes = jax.eval_shape(
        functools.partial(init_caches, cfg, b, s_max))
    csh = named(mesh, cache_specs(cshapes, multi_pod, long_ctx), cshapes)

    def step(params, caches, batch, position):
        return decode_step(params, caches, batch["tokens"], position,
                           cfg, ctx)

    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return CellProgram(
        name=f"{spec.name}:{shape}", fn=step,
        args=(pshapes, cshapes, ispecs, pos),
        in_shardings=(psh, csh, bsh, NamedSharding(mesh, P())),
        out_shardings=(None, csh), meta=meta)


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------


def _gnn_cell(spec: ArchSpec, shape: str, mesh: Mesh,
              multi_pod: bool) -> CellProgram:
    import dataclasses
    from ..models.gnn import gnn_loss, init_gnn_params
    cfg = spec.model_cfg_for(shape)
    sp = spec.shapes[shape]
    # full-batch-large graphs: explicit 1D-distributed message passing
    # (models/gnn_dist.py) — node blocks over "model", edge shards over the
    # data axes, shard_map locality (replicated nodes peak at 151 GiB/dev
    # on ogb_products; see EXPERIMENTS.md §Perf).
    big = sp.kind == "gnn_full" and sp.dims["n_nodes"] > 1_000_000
    if big:
        cfg = dataclasses.replace(cfg, remat=True,
                                  dtype=jnp.bfloat16)
        ctx = None
    else:
        ctx = ShardCtx(mesh=mesh, dp=flat_axes(multi_pod), tp=None)
    pshapes = _eval_params(functools.partial(init_gnn_params, cfg=cfg))
    pspecs = gnn_param_specs(pshapes)
    psh = named(mesh, pspecs, pshapes)
    ispecs = spec.input_specs(shape)
    bspec = batch_specs("gnn", sp.kind, ispecs, multi_pod)
    if big:
        from ..models.gnn_dist import build_dist_loss
        dist_loss, bspec_for = build_dist_loss(
            cfg, mesh, n_total=sp.dims["n_nodes"],
            edge_axes=flat_axes(multi_pod))
        bspec = {k: bspec_for(k, v.ndim) for k, v in ispecs.items()}
    bsh = named(mesh, bspec, ispecs)
    opt_cfg = AdamWConfig()
    oshapes = jax.eval_shape(adamw_init, pshapes)
    osh = named(mesh, opt_state_specs(pspecs), oshapes)

    def train_step(params, opt_state, batch):
        lfn = (dist_loss if big
               else (lambda p, b: gnn_loss(p, b, cfg, ctx)))
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lfn(p, batch), has_aux=True)(params)
        new_p, new_o, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        return new_p, new_o, metrics

    return CellProgram(
        name=f"{spec.name}:{shape}", fn=train_step,
        args=(pshapes, oshapes, ispecs),
        in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None),
        meta={"family": "gnn", "kind": sp.kind, "n_params": cfg.n_params,
              "n_active_params": cfg.n_params, "dims": dict(sp.dims)})


# --------------------------------------------------------------------------
# RecSys cells
# --------------------------------------------------------------------------


def _rec_cell(spec: ArchSpec, shape: str, mesh: Mesh,
              multi_pod: bool) -> CellProgram:
    from ..models.bst import (bst_loss, bst_retrieval, bst_serve,
                              init_bst_params)
    cfg = spec.model_cfg
    sp = spec.shapes[shape]
    ctx = ShardCtx(mesh=mesh, dp=dp_axes(multi_pod), tp="model")
    pshapes = _eval_params(functools.partial(init_bst_params, cfg=cfg))
    pspecs = bst_param_specs(pshapes)
    psh = named(mesh, pspecs, pshapes)
    ispecs = spec.input_specs(shape)
    bsh = named(mesh, batch_specs("recsys", sp.kind, ispecs, multi_pod),
                ispecs)
    meta = {"family": "recsys", "kind": sp.kind, "n_params": cfg.n_params,
            "n_active_params": cfg.n_params, "dims": dict(sp.dims)}

    if sp.kind == "rec_train":
        opt_cfg = AdamWConfig()
        oshapes = jax.eval_shape(adamw_init, pshapes)
        osh = named(mesh, opt_state_specs(pspecs), oshapes)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: bst_loss(p, batch, cfg, ctx),
                has_aux=True)(params)
            new_p, new_o, om = adamw_update(opt_cfg, grads, opt_state,
                                            params)
            metrics = dict(metrics)
            metrics.update(om)
            return new_p, new_o, metrics

        return CellProgram(
            name=f"{spec.name}:{shape}", fn=train_step,
            args=(pshapes, oshapes, ispecs),
            in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None),
            meta=meta)

    if sp.kind == "rec_serve":
        def step(params, batch):
            return bst_serve(params, batch, cfg, ctx)

        return CellProgram(
            name=f"{spec.name}:{shape}", fn=step,
            args=(pshapes, ispecs), in_shardings=(psh, bsh),
            out_shardings=None, meta=meta)

    def step(params, batch):
        return bst_retrieval(params, batch["hist"], batch["user_feats"],
                             batch["cand_ids"], cfg, ctx)

    return CellProgram(
        name=f"{spec.name}:{shape}", fn=step,
        args=(pshapes, ispecs), in_shardings=(psh, bsh),
        out_shardings=None, meta=meta)


# --------------------------------------------------------------------------
# BENU cell (the paper's technique)
# --------------------------------------------------------------------------


def _benu_cell(spec: ArchSpec, shape: str, mesh: Mesh,
               multi_pod: bool) -> CellProgram:
    from ..core.executor import build_benu_step
    from ..core.estimate import GraphStats
    from ..core.pattern import get_pattern
    from ..core.plangen import generate_best_plan
    from ..distributed.rowstore import RowStoreSpec
    cfg = spec.model_cfg
    sp = spec.shapes[shape]
    d = sp.dims
    axis = flat_axes(multi_pod)
    n_shards = 1
    for a in axis:
        n_shards *= mesh.shape[a]
    rps = -(-(cfg.n_vertices + 1) // n_shards)
    store = RowStoreSpec(n=cfg.n_vertices, d=cfg.row_width,
                         n_shards=n_shards, rows_per_shard=rps, hot=cfg.hot)
    stats = GraphStats(n_vertices=cfg.n_vertices,
                       n_edges=cfg.n_vertices * 16)
    plan = generate_best_plan(get_pattern(cfg.pattern), stats)
    n_enu = sum(1 for i in plan.instrs if i.op == "ENU")
    caps = [cfg.batch_per_shard * cfg.cap_mult[min(i, len(cfg.cap_mult) - 1)]
            for i in range(n_enu)]
    caps = [-(-c // n_shards) * n_shards for c in caps]
    step = build_benu_step(plan, store, mesh, axis, caps,
                           cfg.req_cap, rebalance=True)
    ispecs = spec.input_specs(shape)
    # re-derive specs against the actual mesh shard count
    ispecs = {
        "shards": jax.ShapeDtypeStruct((n_shards, rps, cfg.row_width),
                                       jnp.int32),
        "hot_rows": jax.ShapeDtypeStruct((cfg.hot + 1, cfg.row_width),
                                         jnp.int32),
        "starts": jax.ShapeDtypeStruct((n_shards * cfg.batch_per_shard,),
                                       jnp.int32),
        "starts_valid": jax.ShapeDtypeStruct(
            (n_shards * cfg.batch_per_shard,), jnp.bool_),
    }
    bspec = batch_specs("benu", sp.kind, ispecs, multi_pod)
    bsh = {k: NamedSharding(mesh, v) for k, v in bspec.items()}

    def fn(shards, hot_rows, starts, starts_valid):
        return step(shards, hot_rows, starts, starts_valid)

    return CellProgram(
        name=f"benu:{shape}", fn=fn,
        args=(ispecs["shards"], ispecs["hot_rows"], ispecs["starts"],
              ispecs["starts_valid"]),
        in_shardings=(bsh["shards"], bsh["hot_rows"], bsh["starts"],
                      bsh["starts_valid"]),
        out_shardings=None,
        meta={"family": "benu", "kind": sp.kind, "n_params": 0,
              "n_active_params": 0, "dims": dict(d),
              "plan": plan.pretty(), "caps": caps})


# --------------------------------------------------------------------------
# S-BENU cell (streaming/continuous enumeration, one Delta-P_i step)
# --------------------------------------------------------------------------


def _sbenu_cell(spec: ArchSpec, shape: str, mesh: Mesh,
                multi_pod: bool) -> CellProgram:
    from ..core.engine_sbenu_jax import (build_sbenu_enumerator,
                                         sbenu_default_caps)
    from ..core.estimate import GraphStats
    from ..core.pattern import get_pattern
    from ..core.sbenu import generate_best_sbenu_plans
    from ..graph.dynamic import DeviceSnapshot
    cfg = spec.model_cfg
    sp = spec.shapes[shape]
    d = sp.dims
    n, B = d["n_vertices"], d["batch"]
    stats = GraphStats(n_vertices=n, n_edges=n * 8,
                       delta_edges=d["delta_width"])
    plans = generate_best_sbenu_plans(get_pattern(cfg.sbenu_pattern), stats)
    plan = plans[0]                      # lower ΔP_1's delta-frontier step
    caps = sbenu_default_caps(plan, B, d["delta_width"], d["row_width"])
    run = build_sbenu_enumerator(plan, n, caps)
    ispecs = spec.input_specs(shape)
    bspec = batch_specs("benu", sp.kind, ispecs, multi_pod)
    bsh = {k: NamedSharding(mesh, v) for k, v in bspec.items()}
    keys = ("prev_out", "prev_in", "cur_out", "cur_in", "delta_out",
            "delta_out_sign", "delta_in", "delta_in_sign")

    def fn(prev_out, prev_in, cur_out, cur_in, delta_out, delta_out_sign,
           delta_in, delta_in_sign, starts, starts_valid):
        snap = DeviceSnapshot(
            prev_out=prev_out, prev_in=prev_in, cur_out=cur_out,
            cur_in=cur_in, delta_out=delta_out,
            delta_out_sign=delta_out_sign, delta_in=delta_in,
            delta_in_sign=delta_in_sign, n=n)
        return run(snap, starts, starts_valid)

    return CellProgram(
        name=f"sbenu:{shape}", fn=fn,
        args=tuple(ispecs[k] for k in keys) + (ispecs["starts"],
                                               ispecs["starts_valid"]),
        in_shardings=tuple(bsh[k] for k in keys) + (bsh["starts"],
                                                    bsh["starts_valid"]),
        out_shardings=None,
        meta={"family": "benu", "kind": sp.kind, "n_params": 0,
              "n_active_params": 0, "dims": dict(d),
              "plan": plan.pretty(), "caps": caps})


# --------------------------------------------------------------------------


def build_cell(arch: str, shape: str, mesh: Mesh,
               multi_pod: bool = False,
               sharding_mode: str = "fsdp") -> CellProgram:
    spec = get_config(arch)
    if spec.family == "lm":
        return _lm_cell(spec, shape, mesh, multi_pod,
                        sharding_mode=sharding_mode)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh, multi_pod)
    if spec.family == "recsys":
        return _rec_cell(spec, shape, mesh, multi_pod)
    if spec.family == "benu":
        if spec.shapes[shape].kind == "sbenu_enum":
            return _sbenu_cell(spec, shape, mesh, multi_pod)
        return _benu_cell(spec, shape, mesh, multi_pod)
    raise KeyError(spec.family)
