"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --seq 256 --batch 16 --ckpt-dir /tmp/ckpt [--smoke]

On this CPU container you train the smoke-size configs (the quickstart /
examples path); on a real pod the same code runs the full config with the
production mesh (``--mesh pod``). Checkpoint/restart: rerunning the same
command resumes from the latest checkpoint.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", choices=["int8"], default=None)
    args = ap.parse_args()

    from ..configs import get_config
    from ..data.pipelines import LMStream, RecsysStream, FullGraphData
    from ..train.checkpoint import CheckpointManager
    from ..train.loop import TrainLoopConfig, run_training
    from ..train.optimizer import AdamWConfig

    spec = get_config(args.arch)
    if args.smoke:
        spec = spec.smoke()
    cfg = spec.model_cfg

    if spec.family == "lm":
        from ..models.transformer import init_params, loss_fn
        stream = LMStream(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
        init_fn = lambda: init_params(jax.random.PRNGKey(0), cfg)
        lfn = lambda p, b: loss_fn(p, b, cfg)
        batch_fn = stream.batch
    elif spec.family == "recsys":
        from ..models.bst import bst_loss, init_bst_params
        stream = RecsysStream(n_items=cfg.n_items,
                              n_user_feats=cfg.n_user_feats,
                              seq_len=cfg.seq_len,
                              user_feat_len=cfg.user_feat_len,
                              global_batch=args.batch)
        init_fn = lambda: init_bst_params(jax.random.PRNGKey(0), cfg)
        lfn = lambda p, b: bst_loss(p, b, cfg)
        batch_fn = stream.batch
    elif spec.family == "gnn":
        from ..graph.batch import synthetic_full_graph, synthetic_mesh
        from ..models.gnn import gnn_loss, init_gnn_params
        shape = next(iter(spec.shapes.values()))
        cfg = spec.model_cfg_for(shape.name)
        if cfg.task == "node_reg":
            gb = synthetic_mesh(shape.dims["n_nodes"],
                                shape.dims["n_edges"], cfg.d_feat,
                                cfg.d_edge)
        else:
            gb = synthetic_full_graph(shape.dims["n_nodes"],
                                      shape.dims["n_edges"] // 2,
                                      cfg.d_feat, cfg.n_out)
        data = FullGraphData(gb)
        init_fn = lambda: init_gnn_params(jax.random.PRNGKey(0), cfg)
        lfn = lambda p, b: gnn_loss(p, b, cfg)
        batch_fn = data
    else:
        raise SystemExit(f"family {spec.family}: use launch/enumerate.py")

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      decay_steps=args.steps)
    hist = run_training(
        lfn, init_fn, batch_fn, opt,
        TrainLoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                        log_every=max(args.steps // 20, 1),
                        grad_compression=args.grad_compression),
        ckpt=ckpt)
    print(f"final loss: {hist['loss'][-1]:.4f} "
          f"(first: {hist['loss'][0]:.4f})")


if __name__ == "__main__":
    main()
