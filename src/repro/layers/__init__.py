"""layers package."""
