"""Attention layers: GQA (with optional QKV bias) and MLA (DeepSeek-V2),
with training, prefill and KV-cache decode paths.

Implementation notes
--------------------
* ``blockwise_attention`` is the jnp flash formulation (online softmax over
  KV chunks via lax.scan): linear memory in sequence length, so the 32k
  prefill cells lower/compile without a [T, T] score buffer even on the CPU
  dry-run backend. On TPU the Pallas kernel takes over (kernels/ops.py).
* Decode attends a [B, S, ...] cache with one new token; a softmax over a
  *sharded* S axis compiles to per-shard partials + all-reduce (max / sum) —
  i.e. XLA's SPMD partitioner derives flash-decode for the long_500k cell.
* MLA decode uses the matrix-absorption trick: scores are computed directly
  in the compressed latent space, so the cache stays [B, S, r + rope]
  (the whole point of MLA).

Layouts: activations [B, T, H, d]; caches [B, S, H_kv, d] (GQA) or
[B, S, r] + [B, S, rope] (MLA).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .common import ShardCtx, dense_init, rmsnorm, split_keys
from .rope import apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Core attention math
# --------------------------------------------------------------------------


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, block: int = 1024,
                        scale: Optional[float] = None) -> jax.Array:
    """Online-softmax attention. q: [B, Tq, H, dq]; k: [B, Tk, H, dq];
    v: [B, Tk, H, dv]. Heads must already be expanded/grouped equal."""
    b, tq, h, dq = q.shape
    tk, dv = k.shape[1], v.shape[-1]
    if scale is None:
        scale = dq ** -0.5
    block = min(block, tk)
    pad = (-tk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (tk + pad) // block
    kb = jnp.moveaxis(k.reshape(b, nb, block, h, dq), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block, h, dv), 1, 0)
    qf = q.astype(jnp.float32)
    qpos = jnp.arange(tq) + (tk - tq)          # global positions of queries

    def step(carry, xs):
        m, l, acc, j = carry
        kj, vj = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj.astype(jnp.float32)) * scale
        kpos = j * block + jnp.arange(block)
        mask = kpos[None, :] < tk              # padding
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new, j + 1), None

    m0 = jnp.full((b, h, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    acc0 = jnp.zeros((b, h, tq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, 0), (kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).astype(q.dtype)
    return jnp.moveaxis(out, 1, 2)             # [B, Tq, H, dv]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-step GQA decode. q: [B, 1, Hq, d]; caches [B, S, Hkv, d];
    ``length``: number of valid cache entries (scalar or [B]).

    The q heads are *grouped* against the unexpanded KV cache
    (einsum over [B,1,Hkv,G,d] x [B,S,Hkv,d]) — never broadcast/reshape
    the cache itself: with a sequence-sharded cache, an expanded-KV
    broadcast defeats the SPMD partitioner ("involuntary full
    rematerialization") and all-gathers the entire cache per layer
    (measured 18 GiB x n_layers on long_500k; EXPERIMENTS.md §4.4).
    The softmax over the sharded S axis compiles to partial max/sum +
    all-reduce — flash-decode, derived by XLA."""
    b, _, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(b, 1, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                   k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos[None, :] < jnp.reshape(length, (-1, 1))    # [B, S]
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def _expand_kv(x: jax.Array, groups: int) -> jax.Array:
    """[B, T, Hkv, d] -> [B, T, Hkv*groups, d] by repeat."""
    if groups == 1:
        return x
    b, t, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, t, h, groups, d)
                            ).reshape(b, t, h * groups, d)


def full_attention(q, k, v, causal=True, impl: str = "auto",
                   scale=None) -> jax.Array:
    """Dispatch: Pallas flash kernel on TPU, blockwise jnp elsewhere.
    q/k/v: [B, T, H, d] (equal heads)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "blockwise"
    if impl == "pallas" or impl == "interpret":
        qt = jnp.moveaxis(q, 2, 1)
        kt = jnp.moveaxis(k, 2, 1)
        vt = jnp.moveaxis(v, 2, 1)
        out = kops.flash_attention(qt, kt, vt, causal=causal, scale=scale,
                                   impl=impl)
        return jnp.moveaxis(out, 1, 2)
    return blockwise_attention(q, k, v, causal=causal, scale=scale)


# --------------------------------------------------------------------------
# GQA attention layer
# --------------------------------------------------------------------------


def gqa_params(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
               qkv_bias: bool, dtype) -> Dict:
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    p = {
        "wq": dense_init(ks["wq"], (d_model, n_heads * d_head), dtype),
        "wk": dense_init(ks["wk"], (d_model, n_kv * d_head), dtype),
        "wv": dense_init(ks["wv"], (d_model, n_kv * d_head), dtype),
        "wo": dense_init(ks["wo"], (n_heads * d_head, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
    return p


def gqa_attention(p: Dict, x: jax.Array, positions: jax.Array,
                  cfg, ctx: ShardCtx,
                  cache: Optional[Dict] = None,
                  attn_impl: str = "auto"
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B, T, D]. With ``cache`` (decode): T == 1; cache = {k, v, length};
    returns (out [B, T, D], updated cache or None)."""
    b, t, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("btd,df->btf", x, p["wq"])
    k = jnp.einsum("btd,df->btf", x, p["wk"])
    v = jnp.einsum("btd,df->btf", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = ctx.shard(q.reshape(b, t, h, dh), ctx.dp, None, ctx.tp, None)
    k = k.reshape(b, t, kvh, dh)
    v = v.reshape(b, t, kvh, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        length = cache["length"]
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, length, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, length, 0, 0))
        out = decode_attention(q, k_cache, v_cache, length + t)
        new_cache = {"k": k_cache, "v": v_cache, "length": length + t}
    else:
        kx = _expand_kv(k, h // kvh)
        vx = _expand_kv(v, h // kvh)
        out = full_attention(q, kx, vx, causal=True, impl=attn_impl)
        new_cache = None
    out = out.reshape(b, t, h * dh)
    out = jnp.einsum("btf,fd->btd", out, p["wo"])
    return ctx.shard(out, ctx.dp, None, None), new_cache


# --------------------------------------------------------------------------
# MLA attention layer (DeepSeek-V2-Lite: no q compression)
# --------------------------------------------------------------------------


def mla_params(key, d_model: int, n_heads: int, kv_lora: int,
               qk_nope: int, qk_rope: int, v_dim: int, dtype) -> Dict:
    ks = split_keys(key, ["wq", "wkv_a", "wkv_b", "wo", "norm_ckv"])
    return {
        "wq": dense_init(ks["wq"], (d_model, n_heads * (qk_nope + qk_rope)),
                         dtype),
        "wkv_a": dense_init(ks["wkv_a"], (d_model, kv_lora + qk_rope),
                            dtype),
        "wkv_b": dense_init(ks["wkv_b"], (kv_lora, n_heads *
                                          (qk_nope + v_dim)), dtype),
        "wo": dense_init(ks["wo"], (n_heads * v_dim, d_model), dtype),
        "norm_ckv": jnp.ones((kv_lora,), dtype),
    }


def mla_attention(p: Dict, x: jax.Array, positions: jax.Array,
                  cfg, ctx: ShardCtx,
                  cache: Optional[Dict] = None,
                  attn_impl: str = "auto"
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    b, t, _ = x.shape
    h = cfg.n_heads
    r, nope, rope_d, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                           cfg.qk_rope_dim, cfg.v_head_dim)
    scale = (nope + rope_d) ** -0.5

    q = jnp.einsum("btd,df->btf", x, p["wq"]).reshape(b, t, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("btd,df->btf", x, p["wkv_a"])
    c_kv = rmsnorm(kv_a[..., :r], p["norm_ckv"])
    k_rope = apply_rope(kv_a[..., None, r:], positions, cfg.rope_theta)

    wkv_b = p["wkv_b"].reshape(r, h, nope + vd)
    wk_b, wv_b = wkv_b[..., :nope], wkv_b[..., nope:]

    if cache is not None:
        length = cache["length"]
        ckv_cache = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, length, 0))
        krope_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :].astype(
                cache["k_rope"].dtype), (0, length, 0))
        # -- absorbed decode: score in latent space
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope.astype(jnp.float32),
                           wk_b.astype(jnp.float32))
        s = jnp.einsum("bthr,bsr->bhts", q_lat,
                       ckv_cache.astype(jnp.float32))
        s = s + jnp.einsum("bthc,bsc->bhts", q_rope.astype(jnp.float32),
                           krope_cache.astype(jnp.float32))
        s = s * scale
        kpos = jnp.arange(ckv_cache.shape[1])
        mask = kpos[None, :] < jnp.reshape(length + t, (-1, 1))
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum("bhts,bsr->bthr", pr,
                             ckv_cache.astype(jnp.float32))
        out = jnp.einsum("bthr,rhv->bthv", ctx_lat,
                         wv_b.astype(jnp.float32)).astype(x.dtype)
        new_cache = {"c_kv": ckv_cache, "k_rope": krope_cache,
                     "length": length + t}
    else:
        # -- expanded train/prefill
        k_nope = jnp.einsum("btr,rhn->bthn", c_kv, wk_b)
        vv = jnp.einsum("btr,rhv->bthv", c_kv, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, t, h, rope_d))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = ctx.shard(qq, ctx.dp, None, ctx.tp, None)
        out = full_attention(qq, k, vv, causal=True, impl=attn_impl,
                             scale=scale)
        new_cache = None
    out = out.reshape(b, t, h * vd)
    out = jnp.einsum("btf,fd->btd", out, p["wo"])
    return ctx.shard(out, ctx.dp, None, None), new_cache


def init_gqa_cache(b: int, s_max: int, n_kv: int, d_head: int, dtype):
    return {"k": jnp.zeros((b, s_max, n_kv, d_head), dtype),
            "v": jnp.zeros((b, s_max, n_kv, d_head), dtype),
            "length": jnp.zeros((), jnp.int32)}


def init_mla_cache(b: int, s_max: int, kv_lora: int, qk_rope: int, dtype):
    return {"c_kv": jnp.zeros((b, s_max, kv_lora), dtype),
            "k_rope": jnp.zeros((b, s_max, qk_rope), dtype),
            "length": jnp.zeros((), jnp.int32)}
