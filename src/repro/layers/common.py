"""Shared layer utilities: sharding context, init helpers, norms.

The model code is framework-free (pure params-pytree + functions). Sharding
is expressed through a :class:`ShardCtx` — a thin wrapper over
``jax.lax.with_sharding_constraint`` that becomes a no-op when no mesh is
active (CPU smoke tests) and applies :class:`~jax.sharding.NamedSharding`
constraints during pjit tracing (dry-run / production).

Axis conventions (see launch/mesh.py):
    dp axes   — batch-parallel axes ("data", plus "pod" when multi-pod)
    tp axis   — "model" (tensor/TP, experts, vocab, KV-sequence in decode)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops as kops

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardCtx:
    """Mesh + axis naming used by model code for activation constraints."""

    mesh: Optional[Mesh] = None
    dp: Axis = None        # batch axes, e.g. ("pod", "data") or "data"
    tp: Axis = None        # model axis

    def shard(self, x: jax.Array, *axes: Axis) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*axes)))

    @property
    def dp_size(self) -> int:
        if self.mesh is None or self.dp is None:
            return 1
        axes = (self.dp,) if isinstance(self.dp, str) else self.dp
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


NO_SHARD = ShardCtx()


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    """LeCun-normal (fan-in) init used across the stack."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def split_keys(key, names: Sequence[str]):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))


# --------------------------------------------------------------------------
# Norms / activations
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    return kops.rmsnorm(x, gamma, eps=eps)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def swish(x):
    return x * jax.nn.sigmoid(x)


# --------------------------------------------------------------------------
# Cross entropy (vocab-sharding friendly)
# --------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          z_loss: float = 0.0):
    """Mean CE over all positions; logits [.., V] f32-accumulated.

    Written as logsumexp - label logit so XLA keeps the reduction local to
    vocab shards (one psum), never materializing the softmax.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss
