"""EmbeddingBag: multi-hot gather-reduce over huge sparse tables.

JAX has no native ``nn.EmbeddingBag`` (taxonomy §RecSys) — this is the
``jnp.take`` + ``jax.ops.segment_sum`` construction, padded-id aware. Tables
are row-sharded over the "model" axis in production (the DistributedRowStore
idea applied to embeddings); XLA turns the gather into the appropriate
collective.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_lookup(table: jax.Array, ids: jax.Array,
                     pad_id: Optional[int] = None) -> jax.Array:
    """Row gather with optional padding id -> zero vector. ids: any shape."""
    out = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    if pad_id is not None:
        out = jnp.where((ids == pad_id)[..., None], 0.0, out)
    return out


def embedding_bag(table: jax.Array, ids: jax.Array,
                  segment_ids: jax.Array, num_segments: int,
                  mode: str = "sum", pad_id: Optional[int] = None,
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """Ragged bag-reduce: rows ``table[ids]`` reduced per ``segment_ids``.

    ids, segment_ids: int32[L] (flattened ragged bags); returns
    [num_segments, dim]. ``mode``: sum | mean | max.
    """
    rows = embedding_lookup(table, ids, pad_id=pad_id)
    if weights is not None:
        rows = rows * weights[..., None]
    if mode == "max":
        neg = jnp.full_like(rows, -jnp.inf)
        rows = jnp.where((ids == pad_id)[..., None], neg, rows) \
            if pad_id is not None else rows
        out = jax.ops.segment_max(rows, segment_ids,
                                  num_segments=num_segments)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    out = jax.ops.segment_sum(rows, segment_ids,
                              num_segments=num_segments)
    if mode == "mean":
        valid = jnp.ones_like(ids, jnp.float32)
        if pad_id is not None:
            valid = jnp.where(ids == pad_id, 0.0, valid)
        cnt = jax.ops.segment_sum(valid, segment_ids,
                                  num_segments=num_segments)
        out = out / jnp.maximum(cnt, 1.0)[..., None]
    return out


def embedding_bag_fixed(table: jax.Array, ids: jax.Array,
                        mode: str = "mean",
                        pad_id: Optional[int] = None) -> jax.Array:
    """Dense-rectangular bags: ids [B, L] -> [B, dim] (pad-aware mean/sum)."""
    rows = embedding_lookup(table, ids, pad_id=pad_id)       # [B, L, d]
    if mode == "sum":
        return jnp.sum(rows, axis=1)
    valid = jnp.ones(ids.shape, jnp.float32) if pad_id is None else \
        (ids != pad_id).astype(jnp.float32)
    s = jnp.sum(rows, axis=1)
    return s / jnp.maximum(jnp.sum(valid, axis=1), 1.0)[..., None]
