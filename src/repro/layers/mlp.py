"""Dense FFN blocks: SwiGLU (LLaMA-style gated) MLP."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, split_keys, swish


def swiglu_params(key, d_model: int, d_ff: int, dtype) -> Dict:
    ks = split_keys(key, ["gate", "up", "down"])
    return {
        "w_gate": dense_init(ks["gate"], (d_model, d_ff), dtype),
        "w_up": dense_init(ks["up"], (d_model, d_ff), dtype),
        "w_down": dense_init(ks["down"], (d_ff, d_model), dtype),
    }


def swiglu(p: Dict, x: jax.Array, ctx: ShardCtx) -> jax.Array:
    h = swish(jnp.einsum("btd,df->btf", x, p["w_gate"]))
    h = h * jnp.einsum("btd,df->btf", x, p["w_up"])
    h = ctx.shard(h, ctx.dp, None, ctx.tp)
    out = jnp.einsum("btf,fd->btd", h, p["w_down"])
    return ctx.shard(out, ctx.dp, None, None)


def mlp_params(key, sizes, dtype, bias: bool = True) -> Dict:
    """Plain ReLU MLP tower (recsys / GNN substrate). sizes = [in, h1, .., out]."""
    ps = {}
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        ps[f"w{i}"] = dense_init(keys[i], (a, b), dtype)
        if bias:
            ps[f"b{i}"] = jnp.zeros((b,), dtype)
    return ps


def mlp_apply(p: Dict, x: jax.Array, act=jax.nn.relu,
              final_act: bool = False) -> jax.Array:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = jnp.einsum("...a,ab->...b", x, p[f"w{i}"])
        if f"b{i}" in p:
            x = x + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x
