"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Dispatch design (TPU-native, GShard-equivalent semantics without the
[T, E, C] one-hot blow-up):

    1. router logits -> top-k experts per token (softmax-normalized gates);
    2. flatten (token, choice) assignments, sort by expert id;
    3. position-within-expert = rank - first-rank-of-expert (vectorized via
       searchsorted on the sorted expert column);
    4. scatter token indices into an [E, C] slot table (capacity
       C = ceil(T*k/E * capacity_factor); slots beyond C are dropped —
       standard capacity-factor semantics, droppable tokens keep their
       residual path);
    5. gather tokens -> [E, C, D], batched per-expert GEMMs (einsum over the
       expert axis, sharded over "model"), weighted scatter-add back.

Shared experts (DeepSeek-MoE style) run as a dense SwiGLU on every token.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, split_keys, swish
from .mlp import swiglu, swiglu_params


def moe_params(key, d_model: int, n_experts: int, d_ff: int,
               n_shared: int, dtype) -> Dict:
    ks = split_keys(key, ["router", "gate", "up", "down", "shared"])
    p = {
        "router": dense_init(ks["router"], (d_model, n_experts),
                             jnp.float32),
        "w_gate": dense_init(ks["gate"], (n_experts, d_model, d_ff), dtype),
        "w_up": dense_init(ks["up"], (n_experts, d_model, d_ff), dtype),
        "w_down": dense_init(ks["down"], (n_experts, d_ff, d_model), dtype),
    }
    if n_shared > 0:
        p["shared"] = swiglu_params(ks["shared"], d_model,
                                    d_ff * n_shared, dtype)
    return p


def moe_ffn(p: Dict, x: jax.Array, ctx: ShardCtx, *, top_k: int,
            capacity_factor: float = 1.25,
            aux_loss_weight: float = 0.01
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, D] -> (out [B, T, D], aux load-balance loss scalar)."""
    b, t, d = x.shape
    e = p["router"].shape[1]
    n_tok = b * t
    xf = x.reshape(n_tok, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # [N, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # -- aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1),
        axis=0)
    aux = aux_loss_weight * e * jnp.sum(me * ce)

    # -- sort-based dispatch
    cap = int(max(1, round(n_tok * top_k / e * capacity_factor)))
    flat_expert = expert_idx.reshape(-1)                     # [N*k]
    flat_tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_tok[order], flat_gate[order]
    first = jnp.searchsorted(se, se, side="left").astype(jnp.int32)
    slot = jnp.arange(n_tok * top_k, dtype=jnp.int32) - first
    keep = slot < cap

    slot_tok = jnp.full((e, cap), n_tok, jnp.int32)          # n_tok = pad id
    slot_tok = slot_tok.at[se, slot].set(
        jnp.where(keep, st, n_tok), mode="drop")
    slot_gate = jnp.zeros((e, cap), jnp.float32)
    slot_gate = slot_gate.at[se, slot].set(
        jnp.where(keep, sg, 0.0), mode="drop")

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xin = xpad[slot_tok]                                     # [E, C, D]
    xin = ctx.shard(xin, ctx.tp, None, None)

    h = swish(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    yexp = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # [E, C, D]
    yexp = yexp * slot_gate[..., None].astype(yexp.dtype)

    out = jnp.zeros((n_tok + 1, d), yexp.dtype)
    out = out.at[slot_tok.reshape(-1)].add(
        yexp.reshape(-1, d), mode="drop")
    out = out[:n_tok]

    if "shared" in p:
        out = out + swiglu(p["shared"], x, ctx).reshape(n_tok, d)
    out = ctx.shard(out.reshape(b, t, d), ctx.dp, None, None)
    return out, aux
