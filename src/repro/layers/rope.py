"""Rotary position embeddings (RoPE), plus the decoupled-RoPE split used by
MLA (DeepSeek-V2): only the `rope` slice of each head is rotated."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(d: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies [d/2] (f32)."""
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """Rotate pairs. x: [..., T, H, d] (or [..., T, d]); positions: [..., T].

    Pairing convention: (x[..., :d/2], x[..., d/2:]) halves (NeoX style).
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                          # [d/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., T, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:                          # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)
