"""models package."""
