"""Behavior Sequence Transformer (BST, Alibaba — arXiv:1905.06874).

CTR model: the user's behavior sequence (last ``seq_len`` item ids) plus the
target item are embedded (item embedding + learned position embedding), run
through ``n_blocks`` transformer encoder blocks (8 heads, post-LN as in the
paper), concatenated with "other features" (here: a multi-hot user-profile
field reduced through :func:`embedding_bag_fixed` — the taxonomy's
gather+segment-reduce EmbeddingBag), and scored by a 1024-512-256 MLP.

The item table is the hot path (10^6 rows); in production it is row-sharded
over the "model" axis — the same DistributedRowStore layout the BENU engine
uses for adjacency rows.

Step functions cover the four assigned shape cells:
    train_batch    bce loss + grads over batch=65,536
    serve_p99      batched scoring, batch=512
    serve_bulk     offline scoring, batch=262,144
    retrieval_cand one user vs 1M candidate items: the user tower runs once,
                   candidates are scored by a batched MLP over the candidate
                   axis (no loop; candidates sharded over the whole mesh)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..layers.common import ShardCtx, dense_init, embed_init, layernorm, \
    split_keys
from ..layers.embedding_bag import embedding_bag_fixed, embedding_lookup
from ..layers.mlp import mlp_apply, mlp_params


@dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 1_000_000
    n_user_feats: int = 100_000        # multi-hot profile vocab
    user_feat_len: int = 32            # multi-hot bag width
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    d_ff_mult: int = 4
    mlp_sizes: Tuple[int, ...] = (1024, 512, 256)
    dropout: float = 0.0               # inference/benchmark profile
    dtype: Any = jnp.float32

    @property
    def d_head(self) -> int:
        return self.embed_dim // self.n_heads

    @property
    def concat_dim(self) -> int:
        # (seq + target) flattened transformer output + user-profile bag
        return (self.seq_len + 1) * self.embed_dim + self.embed_dim

    @property
    def n_params(self) -> int:
        import numpy as np
        params = jax.eval_shape(lambda k: init_bst_params(k, self),
                                jax.random.PRNGKey(0))
        return int(sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(params)))


def init_bst_params(key, cfg: BSTConfig) -> Dict:
    ks = split_keys(key, ["item", "pos", "user", "wq", "wk", "wv", "wo",
                          "ff1", "ff2", "mlp", "ln1", "ln2"])
    d = cfg.embed_dim
    blocks = []
    bk = jax.random.split(ks["wq"], cfg.n_blocks)
    for k in bk:
        kk = split_keys(k, ["wq", "wk", "wv", "wo", "ff1", "ff2"])
        blocks.append({
            "wq": dense_init(kk["wq"], (d, d), cfg.dtype),
            "wk": dense_init(kk["wk"], (d, d), cfg.dtype),
            "wv": dense_init(kk["wv"], (d, d), cfg.dtype),
            "wo": dense_init(kk["wo"], (d, d), cfg.dtype),
            "ff1": dense_init(kk["ff1"], (d, d * cfg.d_ff_mult), cfg.dtype),
            "ff2": dense_init(kk["ff2"], (d * cfg.d_ff_mult, d), cfg.dtype),
            "ln1_g": jnp.ones((d,), cfg.dtype),
            "ln1_b": jnp.zeros((d,), cfg.dtype),
            "ln2_g": jnp.ones((d,), cfg.dtype),
            "ln2_b": jnp.zeros((d,), cfg.dtype),
        })
    return {
        "item_emb": embed_init(ks["item"], (cfg.n_items, d), cfg.dtype),
        "pos_emb": embed_init(ks["pos"], (cfg.seq_len + 1, d), cfg.dtype),
        "user_emb": embed_init(ks["user"], (cfg.n_user_feats, d), cfg.dtype),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "mlp": mlp_params(ks["mlp"], [cfg.concat_dim] +
                          list(cfg.mlp_sizes) + [1], cfg.dtype),
    }


def _encoder_block(bp: Dict, x: jax.Array, cfg: BSTConfig,
                   ctx: ShardCtx) -> jax.Array:
    """Post-LN transformer block over the short (seq_len+1) axis."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = jnp.einsum("btd,df->btf", x, bp["wq"]).reshape(b, t, h, dh)
    k = jnp.einsum("btd,df->btf", x, bp["wk"]).reshape(b, t, h, dh)
    v = jnp.einsum("btd,df->btf", x, bp["wv"]).reshape(b, t, h, dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (dh ** -0.5)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, t, d)
    o = jnp.einsum("btf,fd->btd", o, bp["wo"])
    x = layernorm(x + o, bp["ln1_g"], bp["ln1_b"])
    f = jax.nn.relu(jnp.einsum("btd,df->btf", x, bp["ff1"]))
    f = jnp.einsum("btf,fd->btd", f, bp["ff2"])
    return layernorm(x + f, bp["ln2_g"], bp["ln2_b"])


def user_tower(params: Dict, hist: jax.Array, user_feats: jax.Array,
               cfg: BSTConfig, ctx: ShardCtx) -> jax.Array:
    """hist [B, L] item ids; user_feats [B, W] multi-hot (pad=0) ->
    [B, seq_len*d + d] user-side representation (target slot excluded)."""
    b = hist.shape[0]
    e_hist = embedding_lookup(params["item_emb"], hist)      # [B, L, d]
    e_hist = ctx.shard(e_hist, ctx.dp, None, None)
    e_user = embedding_bag_fixed(params["user_emb"], user_feats,
                                 mode="mean", pad_id=0)      # [B, d]
    return e_hist, e_user


def bst_scores(params: Dict, hist: jax.Array, target: jax.Array,
               user_feats: jax.Array, cfg: BSTConfig,
               ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """CTR logits [B]. hist [B, L]; target [B]; user_feats [B, W]."""
    b = hist.shape[0]
    e_hist, e_user = user_tower(params, hist, user_feats, cfg, ctx)
    e_tgt = embedding_lookup(params["item_emb"], target)[:, None, :]
    seq = jnp.concatenate([e_hist, e_tgt], axis=1)           # [B, L+1, d]
    seq = seq + params["pos_emb"][None, :, :]

    def body(x, bp):
        return _encoder_block(bp, x, cfg, ctx), None

    seq, _ = jax.lax.scan(body, seq, params["blocks"])
    flat = seq.reshape(b, -1)
    feats = jnp.concatenate([flat, e_user], axis=-1)
    feats = ctx.shard(feats, ctx.dp, None)
    return mlp_apply(params["mlp"], feats)[..., 0]


def bst_loss(params: Dict, batch: Dict, cfg: BSTConfig,
             ctx: ShardCtx = ShardCtx()):
    logits = bst_scores(params, batch["hist"], batch["target"],
                        batch["user_feats"], cfg, ctx)
    labels = batch["label"].astype(jnp.float32)
    lf = logits.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(lf, 0) - lf * labels +
                    jnp.log1p(jnp.exp(-jnp.abs(lf))))
    acc = jnp.mean(((lf > 0) == (labels > 0.5)).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def bst_serve(params: Dict, batch: Dict, cfg: BSTConfig,
              ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """Online/bulk scoring: sigmoid CTR for each (user, target) row."""
    return jax.nn.sigmoid(bst_scores(params, batch["hist"], batch["target"],
                                     batch["user_feats"], cfg, ctx))


def bst_retrieval(params: Dict, hist: jax.Array, user_feats: jax.Array,
                  cand_ids: jax.Array, cfg: BSTConfig,
                  ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """Retrieval scoring: one user (hist [1, L]) vs cand_ids [C].

    The transformer runs once per *candidate slot* only in its last
    position; we factor the computation: encoder blocks attend over
    [hist ; cand] but the history-side K/V are shared. For the assigned
    cell (C = 10^6) the dominant cost is the candidate-side MLP — a batched
    matmul over C rows, sharded over the full mesh; no loops.
    """
    L, d = cfg.seq_len, cfg.embed_dim
    C = cand_ids.shape[0]
    e_hist, e_user = user_tower(params, hist, user_feats, cfg, ctx)
    e_hist = e_hist + params["pos_emb"][None, :L, :]
    # candidates sharded over every mesh axis (flattened)
    def _flat_axes():
        axes = []
        for a in (ctx.dp, ctx.tp):
            if a is None:
                continue
            axes.extend((a,) if isinstance(a, str) else a)
        return tuple(axes) or None
    cand_axis = _flat_axes()
    e_cand = embedding_lookup(params["item_emb"], cand_ids)  # [C, d]
    e_cand = ctx.shard(e_cand + params["pos_emb"][L], cand_axis, None)

    # single-block factored attention per candidate (n_blocks == 1 for BST):
    bp = jax.tree.map(lambda x: x[0], params["blocks"])
    hist_tokens = e_hist[0]                                  # [L, d]
    # history tokens attend among themselves + each candidate; candidate
    # attends history + itself. We evaluate the block exactly per candidate
    # by batching candidates as the batch axis of the encoder.
    seqs = jnp.concatenate(
        [jnp.broadcast_to(hist_tokens[None], (C, L, d)),
         e_cand[:, None, :]], axis=1)                        # [C, L+1, d]
    out = _encoder_block(bp, seqs, cfg, ctx)                 # [C, L+1, d]
    flat = out.reshape(C, -1)
    feats = jnp.concatenate(
        [flat, jnp.broadcast_to(e_user, (C, d))], axis=-1)
    return mlp_apply(params["mlp"], feats)[..., 0]
