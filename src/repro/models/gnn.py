"""The four assigned GNN architectures over the segment-sum substrate.

    gin-tu          GIN (sum aggregator, learnable eps), 5 x 64
    pna             Principal Neighbourhood Aggregation: {mean,max,min,std}
                    x {identity, amplification, attenuation}, 4 x 75
    egnn            E(n)-equivariant GNN (scalar-distance messages +
                    coordinate updates), 4 x 64
    meshgraphnet    encode-process-decode with edge+node MLP blocks, 15 x 128

All message passing is `gather(src) -> edge compute -> segment_sum(dst)`;
padded edges scatter into a dropped extra segment. Distribution (full-batch
cells): edge arrays are sharded over the combined data axes, node tensors
replicated — each device scatters its edge shard and XLA inserts one
all-reduce per layer (see DESIGN.md §6; the ogb_products hillclimb attacks
exactly this collective).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..layers.common import ShardCtx, dense_init, layernorm, split_keys
from ..layers.mlp import mlp_apply, mlp_params


def _ln_params(d: int, dtype) -> Dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(p: Dict, x: jax.Array) -> jax.Array:
    return layernorm(x, p["g"], p["b"])


@dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                  # gin | pna | egnn | mgn
    n_layers: int
    d_hidden: int
    d_feat: int
    n_out: int                 # classes or regression dims
    task: str = "node_class"   # node_class | graph_class | node_reg
    d_edge: int = 0            # mgn edge-feature dim
    mlp_layers: int = 2
    dtype: Any = jnp.float32
    shard_nodes: bool = False  # 1D node partition over ctx.tp (big graphs)
    remat: bool = False        # recompute layer internals in backward

    @property
    def n_params(self) -> int:
        import numpy as np
        # counted exactly from an abstract init
        params = jax.eval_shape(lambda k: init_gnn_params(k, self),
                                jax.random.PRNGKey(0))
        return int(sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(params)))


# --------------------------------------------------------------------------
# Message-passing primitives
# --------------------------------------------------------------------------


def gather_src(h: jax.Array, src: jax.Array, n: int) -> jax.Array:
    """h: [N, d]; src: [E] with sentinel == n -> zeros row."""
    hp = jnp.concatenate([h, jnp.zeros((1,) + h.shape[1:], h.dtype)], axis=0)
    return hp[jnp.clip(src, 0, n)]


def scatter_sum(msg: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    return jax.ops.segment_sum(msg, jnp.clip(dst, 0, n),
                               num_segments=n + 1)[:n]


def scatter_max(msg: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    out = jax.ops.segment_max(msg, jnp.clip(dst, 0, n),
                              num_segments=n + 1)[:n]
    return jnp.where(jnp.isfinite(out), out, 0.0)


def scatter_min(msg: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    out = jax.ops.segment_min(msg, jnp.clip(dst, 0, n),
                              num_segments=n + 1)[:n]
    return jnp.where(jnp.isfinite(out), out, 0.0)


def in_degree(dst: jax.Array, n: int, emask: jax.Array) -> jax.Array:
    return jax.ops.segment_sum(emask.astype(jnp.float32),
                               jnp.clip(dst, 0, n), num_segments=n + 1)[:n]


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init_gnn_params(key, cfg: GNNConfig) -> Dict:
    ks = split_keys(key, ["enc", "enc_e", "layers", "dec"])
    d = cfg.d_hidden
    p: Dict = {"enc": mlp_params(ks["enc"], [cfg.d_feat, d, d], cfg.dtype)}
    lk = jax.random.split(ks["layers"], cfg.n_layers)
    layers = []
    for k in lk:
        kk = split_keys(k, ["a", "b", "c"])
        if cfg.kind == "gin":
            lp = {"mlp": mlp_params(kk["a"], [d, d, d], cfg.dtype),
                  "eps": jnp.zeros((), cfg.dtype),
                  "ln": _ln_params(d, cfg.dtype)}
        elif cfg.kind == "pna":
            lp = {"pre": mlp_params(kk["a"], [2 * d, d], cfg.dtype),
                  "post": mlp_params(kk["b"], [13 * d, d], cfg.dtype)}
        elif cfg.kind == "egnn":
            lp = {"phi_e": mlp_params(kk["a"], [2 * d + 1, d, d], cfg.dtype),
                  "phi_x": mlp_params(kk["b"], [d, d, 1], cfg.dtype),
                  "phi_h": mlp_params(kk["c"], [2 * d, d, d], cfg.dtype)}
        elif cfg.kind == "mgn":
            lp = {"edge_mlp": mlp_params(kk["a"], [3 * d, d, d], cfg.dtype),
                  "node_mlp": mlp_params(kk["b"], [2 * d, d, d], cfg.dtype),
                  "edge_ln": _ln_params(d, cfg.dtype),
                  "node_ln": _ln_params(d, cfg.dtype)}
        else:
            raise ValueError(cfg.kind)
        layers.append(lp)
    p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    if cfg.kind == "mgn":
        p["enc_e"] = mlp_params(ks["enc_e"], [cfg.d_edge, d, d], cfg.dtype)
    p["dec"] = mlp_params(ks["dec"], [d, d, cfg.n_out], cfg.dtype)
    return p


# --------------------------------------------------------------------------
# Layer bodies
# --------------------------------------------------------------------------


def _gin_layer(lp, h, e_src, e_dst, emask, n, ctx, node_axis=None):
    hs = gather_src(h, e_src, n)
    hs = ctx.shard(hs, ctx.dp, None)
    agg = scatter_sum(hs, e_dst, n)
    agg = ctx.shard(agg, node_axis, None)
    out = mlp_apply(lp["mlp"], (1.0 + lp["eps"]) * h + agg,
                    act=jax.nn.relu, final_act=True)
    # GIN-TU uses BatchNorm between layers; LayerNorm is the distribution-
    # friendly substitute (no cross-device batch stats) — noted in DESIGN.md
    return _ln(lp["ln"], out)


def _pna_layer(lp, h, e_src, e_dst, emask, n, ctx,
               node_axis=None, delta: float = 2.0):
    hs = gather_src(h, e_src, n)
    hd = gather_src(h, e_dst, n)
    m = mlp_apply(lp["pre"], jnp.concatenate([hs, hd], axis=-1))
    m = jnp.where(emask[:, None], m, 0.0)
    m = ctx.shard(m, ctx.dp, None)
    deg = jnp.maximum(in_degree(e_dst, n, emask), 1.0)
    s_sum = scatter_sum(m, e_dst, n)
    mean = s_sum / deg[:, None]
    mx = scatter_max(jnp.where(emask[:, None], m, -jnp.inf), e_dst, n)
    mn = scatter_min(jnp.where(emask[:, None], m, jnp.inf), e_dst, n)
    sq = scatter_sum(m * m, e_dst, n) / deg[:, None]
    std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-8)
    aggs = [mean, mx, mn, std]
    logd = jnp.log(deg + 1.0)[:, None]
    scaled = []
    for a in aggs:
        scaled += [a, a * logd / delta, a * delta / logd]
    out = mlp_apply(lp["post"],
                    jnp.concatenate([h] + scaled, axis=-1))
    return h + out


def _egnn_layer(lp, h, x, e_src, e_dst, emask, n, ctx, node_axis=None):
    hs, hd = gather_src(h, e_src, n), gather_src(h, e_dst, n)
    xs, xd = gather_src(x, e_src, n), gather_src(x, e_dst, n)
    diff = xd - xs
    r2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
    m = mlp_apply(lp["phi_e"], jnp.concatenate([hd, hs, r2], axis=-1),
                  act=jax.nn.silu, final_act=True)
    m = jnp.where(emask[:, None], m, 0.0)
    m = ctx.shard(m, ctx.dp, None)
    w = mlp_apply(lp["phi_x"], m, act=jax.nn.silu)               # [E, 1]
    deg = jnp.maximum(in_degree(e_dst, n, emask), 1.0)[:, None]
    x_new = x + scatter_sum(diff * w, e_dst, n) / deg
    agg = scatter_sum(m, e_dst, n)
    h_new = h + mlp_apply(lp["phi_h"],
                          jnp.concatenate([h, agg], axis=-1),
                          act=jax.nn.silu)
    return h_new, x_new


def _mgn_layer(lp, h, e_feat, e_src, e_dst, emask, n, ctx,
               node_axis=None):
    hs, hd = gather_src(h, e_src, n), gather_src(h, e_dst, n)
    e_new = _ln(lp["edge_ln"], mlp_apply(
        lp["edge_mlp"], jnp.concatenate([e_feat, hs, hd], axis=-1),
        act=jax.nn.relu)) + e_feat
    e_new = jnp.where(emask[:, None], e_new, 0.0)
    e_new = ctx.shard(e_new, ctx.dp, None)
    agg = scatter_sum(e_new, e_dst, n)
    h_new = _ln(lp["node_ln"], mlp_apply(
        lp["node_mlp"], jnp.concatenate([h, agg], axis=-1),
        act=jax.nn.relu)) + h
    return h_new, e_new


# --------------------------------------------------------------------------
# Forward + loss
# --------------------------------------------------------------------------


def gnn_forward(params: Dict, batch: Dict, cfg: GNNConfig,
                ctx: ShardCtx = ShardCtx()) -> jax.Array:
    n = batch["x"].shape[0]
    # node-tensor placement: replicated by default; 1D partition over the
    # model axis for full-batch-large graphs (ogb_products) — per-layer
    # node state then costs N*d/16 per device instead of N*d (the
    # replicated layout peaks at 151 GiB/device on meshgraphnet;
    # EXPERIMENTS.md §Perf)
    node_axis = ctx.tp if cfg.shard_nodes else None
    e_src = ctx.shard(batch["edge_src"], ctx.dp)
    e_dst = ctx.shard(batch["edge_dst"], ctx.dp)
    emask = e_src < n
    h = mlp_apply(params["enc"], batch["x"].astype(cfg.dtype),
                  act=jax.nn.relu, final_act=True)
    h = h * batch["node_mask"][:, None].astype(h.dtype)
    h = ctx.shard(h, node_axis, None)

    if cfg.kind == "egnn":
        x = batch["pos"].astype(cfg.dtype)

        def body(carry, lp):
            hh, xx = carry
            hh, xx = _egnn_layer(lp, hh, xx, e_src, e_dst, emask, n, ctx,
                                 node_axis)
            return (ctx.shard(hh, node_axis, None), xx), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, x), _ = jax.lax.scan(body, (h, x), params["layers"])
    elif cfg.kind == "mgn":
        ef = mlp_apply(params["enc_e"], batch["edge_attr"].astype(cfg.dtype),
                       act=jax.nn.relu, final_act=True)
        ef = jnp.where(emask[:, None], ef, 0.0)

        def body(carry, lp):
            hh, ee = carry
            hh, ee = _mgn_layer(lp, hh, ee, e_src, e_dst, emask, n, ctx,
                                node_axis)
            return (ctx.shard(hh, node_axis, None), ee), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, _), _ = jax.lax.scan(body, (h, ef), params["layers"])
    else:
        layer = _gin_layer if cfg.kind == "gin" else _pna_layer

        def body(hh, lp):
            out = layer(lp, hh, e_src, e_dst, emask, n, ctx, node_axis)
            return ctx.shard(out, node_axis, None), None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["layers"])

    if cfg.task == "graph_class":
        gid = batch["graph_ids"]
        ng = int(batch["loss_mask"].shape[0])
        pooled = jax.ops.segment_sum(h, gid, num_segments=ng)
        return mlp_apply(params["dec"], pooled)
    return mlp_apply(params["dec"], h)


def gnn_loss(params: Dict, batch: Dict, cfg: GNNConfig,
             ctx: ShardCtx = ShardCtx()):
    out = gnn_forward(params, batch, cfg, ctx)
    mask = batch["loss_mask"].astype(jnp.float32)
    if cfg.task in ("node_class", "graph_class"):
        logits = out.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["labels"][..., None],
                                 axis=-1)[..., 0]
        loss = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        acc = jnp.sum((jnp.argmax(logits, -1) == batch["labels"]) * mask) \
            / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "acc": acc}
    err = (out.astype(jnp.float32) - batch["targets"]) ** 2
    loss = jnp.sum(err * mask[:, None]) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}
