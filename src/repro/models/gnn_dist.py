"""Explicit 1D-distributed message passing for full-batch-large graphs.

Why this exists: expressing node sharding through sharding *constraints*
cannot tell XLA that scatter destinations are block-local, so the
partitioner replicates node state and the [N, d] scatter buffers
(meshgraphnet on ogb_products peaks at 151 GiB/device with replicated
nodes; constraint-based sharding measured 455 GiB — EXPERIMENTS.md §Perf).
Under ``shard_map`` the layout is explicit — the same philosophy as BENU's
DistributedRowStore: partition the state, move *requests*, never replicate.

Layout (mesh axes ("data", "model"); multi-pod adds "pod" to the edge axes):
    node tensors   block-partitioned over "model": [N/S, d] per device,
                   replicated across "data"
    edge tensors   partitioned over "data"(x"pod"): [E/D] per device,
                   replicated across "model"

Per layer each device:
    1. ``all_gather`` node blocks over "model"  -> h_full [N, d]
    2. gather h_full[src] for the local edge shard, compute messages
    3. scatter-add into a transient [N, d] partial
    4. ``psum_scatter`` over "model" + ``psum`` over "data"
       -> aggregated node block [N/S, d]
    (max/min aggregations: ``pmax``/``pmin`` over "data" + local slice)

Wire per device per layer ~ 2 x N x d x (S-1)/S bytes — independent of the
edge count (edges never move): the GNN analogue of "shuffle the graph, not
the matches". Gradients flow through the collectives by transposition
(all_gather <-> psum_scatter), so one ``jax.value_and_grad`` over the
shard_mapped loss trains the model.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..layers.mlp import mlp_apply
from .gnn import GNNConfig, _ln


def _make_sum_block(n_shards: int):
    """[N, d] per-device edge-shard partial sums -> node block [N/S, d].

    Edges are sharded over EVERY mesh axis (each device owns a distinct
    shard), so partials differ device-to-device: reduce-scatter over the
    node axis (sums across its group AND splits rows into blocks), then
    psum across the remaining edge axes.
    """
    def sum_block(partial_full: jax.Array, naxis: str,
                  rest_axes) -> jax.Array:
        blk = jax.lax.psum_scatter(partial_full, naxis,
                                   scatter_dimension=0, tiled=True)
        if rest_axes:
            blk = jax.lax.psum(blk, rest_axes)
        return blk

    return sum_block


def _diff_preduce(axis, op: str):
    """Differentiable pmax/pmin: subgradient routed to the extremal
    contributors (ties share; standard max-pool VJP semantics)."""
    red = jax.lax.pmax if op == "max" else jax.lax.pmin

    @jax.custom_vjp
    def f(x):
        return red(x, axis)

    def fwd(x):
        y = red(x, axis)
        return y, (x, y)

    def bwd(res, g):
        x, y = res
        return (jnp.where(x == y, g, 0.0),)

    f.defvjp(fwd, bwd)
    return f


def _make_minmax_block(n_shards: int):
    def minmax_block(partial_full: jax.Array, naxis: str, all_axes,
                     op: str) -> jax.Array:
        full = _diff_preduce(all_axes, op)(partial_full)
        nloc = full.shape[0] // n_shards
        idx = jax.lax.axis_index(naxis)
        return jax.lax.dynamic_slice_in_dim(full, idx * nloc, nloc, axis=0)

    return minmax_block


def _scatter(msg: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    return jax.ops.segment_sum(msg, jnp.clip(dst, 0, n),
                               num_segments=n + 1)[:n]


def _scatter_max(msg, dst, n, emask, big):
    m = jnp.where(emask[:, None], msg, -big)
    out = jax.ops.segment_max(m, jnp.clip(dst, 0, n),
                              num_segments=n + 1)[:n]
    return jnp.maximum(out, -big)


def _scatter_min(msg, dst, n, emask, big):
    m = jnp.where(emask[:, None], msg, big)
    out = jax.ops.segment_min(m, jnp.clip(dst, 0, n),
                              num_segments=n + 1)[:n]
    return jnp.minimum(out, big)


def build_dist_loss(cfg: GNNConfig, mesh: Mesh, n_total: int,
                    naxis: str = "model",
                    edge_axes: Tuple[str, ...] = ("data", "model")):
    """Returns ``(loss_fn, batch_spec_for)`` (shard_mapped).

    batch: node leaves sharded P(naxis) (replicated across the other
    axes), edge leaves sharded over the FLATTENED ``edge_axes``; params
    replicated.
    """
    BIG = 1e30
    assert naxis in edge_axes, "node axis must be one of the edge axes"
    rest = tuple(a for a in edge_axes if a != naxis)
    _minmax_block = _make_minmax_block(mesh.shape[naxis])
    _sum_block = _make_sum_block(mesh.shape[naxis])
    eaxis = edge_axes  # kept name for the closures below

    def local(params, batch):
        e_src, e_dst = batch["edge_src"], batch["edge_dst"]
        emask = e_src < n_total
        h_blk = mlp_apply(params["enc"], batch["x"].astype(cfg.dtype),
                          act=jax.nn.relu, final_act=True)
        h_blk = h_blk * batch["node_mask"][:, None].astype(h_blk.dtype)
        x_blk = (batch["pos"].astype(cfg.dtype)
                 if cfg.kind == "egnn" else None)

        def gathered(t_blk):
            full = jax.lax.all_gather(t_blk, naxis, tiled=True)
            return jnp.concatenate(
                [full, jnp.zeros((1,) + full.shape[1:], full.dtype)],
                axis=0)

        # in-degree per node block (constant across layers)
        deg_partial = _scatter(emask[:, None].astype(jnp.float32),
                               e_dst, n_total)
        deg_blk = jnp.maximum(_sum_block(deg_partial, naxis, rest),
                              1.0)[:, 0]                       # [N/S]

        def mp_layer(lp, h_blk, e_feat, x_blk):
            hp = gathered(h_blk)
            hs = hp[jnp.clip(e_src, 0, n_total)]
            hd = hp[jnp.clip(e_dst, 0, n_total)]
            x_new = x_blk
            if cfg.kind == "mgn":
                e_new = _ln(lp["edge_ln"], mlp_apply(
                    lp["edge_mlp"],
                    jnp.concatenate([e_feat, hs, hd], axis=-1),
                    act=jax.nn.relu)) + e_feat
                e_new = jnp.where(emask[:, None], e_new, 0.0)
                agg = _sum_block(_scatter(e_new, e_dst, n_total),
                                 naxis, rest)
                h_new = _ln(lp["node_ln"], mlp_apply(
                    lp["node_mlp"],
                    jnp.concatenate([h_blk, agg], axis=-1),
                    act=jax.nn.relu)) + h_blk
                return h_new.astype(cfg.dtype), e_new.astype(cfg.dtype), \
                    x_new
            if cfg.kind == "gin":
                msg = jnp.where(emask[:, None], hs, 0.0)
                agg = _sum_block(_scatter(msg, e_dst, n_total),
                                 naxis, rest)
                h_new = _ln(lp["ln"], mlp_apply(
                    lp["mlp"], (1.0 + lp["eps"]) * h_blk + agg,
                    act=jax.nn.relu, final_act=True))
                return h_new.astype(cfg.dtype), e_feat, x_new
            if cfg.kind == "pna":
                m = mlp_apply(lp["pre"],
                              jnp.concatenate([hs, hd], axis=-1))
                m = jnp.where(emask[:, None], m, 0.0)
                s_sum = _sum_block(_scatter(m, e_dst, n_total),
                                   naxis, rest)
                mean = (s_sum / deg_blk[:, None]).astype(cfg.dtype)
                mx = _minmax_block(
                    _scatter_max(m, e_dst, n_total, emask, BIG),
                    naxis, edge_axes, "max")
                mn = _minmax_block(
                    _scatter_min(m, e_dst, n_total, emask, BIG),
                    naxis, edge_axes, "min")
                mx = jnp.where(mx <= -BIG / 2, 0.0, mx).astype(cfg.dtype)
                mn = jnp.where(mn >= BIG / 2, 0.0, mn).astype(cfg.dtype)
                sq = _sum_block(_scatter(m * m, e_dst, n_total),
                                naxis, rest) / deg_blk[:, None]
                std = jnp.sqrt(
                    jnp.maximum(sq - mean.astype(jnp.float32) ** 2, 0.0)
                    + 1e-8).astype(cfg.dtype)
                logd = jnp.log(deg_blk + 1.0)[:, None].astype(cfg.dtype)
                scaled = []
                for a in (mean, mx, mn, std):
                    scaled += [a, a * logd / 2.0, a * 2.0 / logd]
                h_new = h_blk + mlp_apply(
                    lp["post"],
                    jnp.concatenate([h_blk] + scaled, axis=-1)
                    ).astype(cfg.dtype)
                return h_new.astype(cfg.dtype), e_feat, x_new
            if cfg.kind == "egnn":
                xp = gathered(x_blk)
                xs = xp[jnp.clip(e_src, 0, n_total)]
                xd = xp[jnp.clip(e_dst, 0, n_total)]
                diff = xd - xs
                r2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
                m = mlp_apply(lp["phi_e"],
                              jnp.concatenate([hd, hs, r2], axis=-1),
                              act=jax.nn.silu, final_act=True)
                m = jnp.where(emask[:, None], m, 0.0)
                w = mlp_apply(lp["phi_x"], m, act=jax.nn.silu)
                xagg = _sum_block(_scatter(diff * w, e_dst, n_total),
                                  naxis, rest)
                x_new = (x_blk + xagg / deg_blk[:, None]
                         ).astype(cfg.dtype)
                agg = _sum_block(_scatter(m, e_dst, n_total),
                                 naxis, rest)
                h_new = h_blk + mlp_apply(
                    lp["phi_h"],
                    jnp.concatenate([h_blk, agg], axis=-1),
                    act=jax.nn.silu)
                return h_new.astype(cfg.dtype), e_feat, x_new
            raise ValueError(cfg.kind)

        if cfg.kind == "mgn":
            ef = mlp_apply(params["enc_e"],
                           batch["edge_attr"].astype(cfg.dtype),
                           act=jax.nn.relu, final_act=True)
            ef = jnp.where(emask[:, None], ef, 0.0)
        else:
            ef = jnp.zeros((e_src.shape[0], 1), cfg.dtype)
        if x_blk is None:
            x_blk = jnp.zeros((h_blk.shape[0], 1), cfg.dtype)

        def body(carry, lp):
            hh, ee, xx = carry
            hh, ee, xx = mp_layer(lp, hh, ee, xx)
            return (hh, ee, xx), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (h_blk, _, _), _ = jax.lax.scan(body, (h_blk, ef, x_blk),
                                        params["layers"])
        out = mlp_apply(params["dec"], h_blk)
        mask = batch["loss_mask"].astype(jnp.float32)
        if cfg.task == "node_reg":
            num = jnp.sum(((out.astype(jnp.float32)
                            - batch["targets"]) ** 2) * mask[:, None])
        else:
            logits = out.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, batch["labels"][..., None],
                                     axis=-1)[..., 0]
            num = jnp.sum((lse - ll) * mask)
        den = jnp.maximum(jax.lax.psum(jnp.sum(mask), naxis), 1.0)
        loss = jax.lax.psum(num, naxis) / den
        return loss, {"loss": loss}

    node_spec = P(naxis)
    edge_spec = P(eaxis)

    def batch_spec_for(name: str, ndim: int) -> P:
        if name.startswith("edge"):
            return P(edge_axes, *([None] * (ndim - 1)))
        return P(naxis, *([None] * (ndim - 1)))

    def loss_fn(params, batch):
        rep = jax.tree.map(lambda _: P(), params)
        bspecs = {k: batch_spec_for(k, v.ndim) for k, v in batch.items()}
        fn = shard_map(local, mesh=mesh, in_specs=(rep, bspecs),
                       out_specs=(P(), {"loss": P()}),
                       check_vma=False)
        return fn(params, batch)

    return loss_fn, batch_spec_for
