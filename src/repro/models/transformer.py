"""Decoder-only transformer LM covering the five assigned LM architectures.

One parameterized implementation spans:
    phi4-mini-3.8b      dense, GQA(24/8), RoPE, SwiGLU, 200k vocab
    qwen2-0.5b          dense, GQA(14/2), QKV bias
    qwen2.5-3b          dense, GQA(16/2), QKV bias
    deepseek-v2-lite    MoE (64 routed top-6 + 2 shared), MLA attention
    granite-moe-3b      MoE (40 routed top-8), GQA(24/8)

Layers run under ``jax.lax.scan`` with stacked parameters (HLO stays O(1) in
depth — essential for the 512-device dry-run compile) and optional remat.

Step functions:
    train_step     next-token CE (+ MoE aux loss), grads + AdamW update
                   (built in train/update.py; here: loss_fn / forward)
    prefill_step   full-sequence forward populating a KV cache
    decode_step    one token with KV cache (decode_32k / long_500k cells)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..layers.attention import (gqa_attention, gqa_params, init_gqa_cache,
                                init_mla_cache, mla_attention, mla_params)
from ..layers.common import (ShardCtx, dense_init, embed_init, rmsnorm,
                             softmax_cross_entropy, split_keys)
from ..layers.mlp import swiglu, swiglu_params
from ..layers.moe import moe_ffn, moe_params


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_kind: str = "gqa"              # gqa | mla
    # MLA
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0          # leading dense layers (DeepSeek: 1)
    capacity_factor: float = 1.25
    dtype: Any = jnp.bfloat16
    remat: bool = True

    @property
    def n_params(self) -> int:
        """Total parameter count (for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        if self.attn_kind == "mla":
            attn = (d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * self.d_head * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * self.d_head * d
        if self.moe:
            ffn_moe = (d * self.n_experts + 3 * self.n_experts * d
                       * self.moe_d_ff + 3 * d * self.moe_d_ff
                       * self.n_shared)
            ffn_dense = 3 * d * self.d_ff
            ffn = (ffn_moe * (L - self.first_dense_layers)
                   + ffn_dense * self.first_dense_layers) / L
        else:
            ffn = 3 * d * self.d_ff
        emb = V * d * (1 if self.tie_embeddings else 2)
        return int(L * (attn + ffn + 2 * d) + emb + d)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of routed + shared)."""
        if not self.moe:
            return self.n_params
        d, L = self.d_model, self.n_layers
        if self.attn_kind == "mla":
            attn = (d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * self.d_head * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * self.d_head * d
        ffn_act = (d * self.n_experts
                   + 3 * self.top_k * d * self.moe_d_ff
                   + 3 * d * self.moe_d_ff * self.n_shared)
        ffn_dense = 3 * d * self.d_ff
        ffn = (ffn_act * (L - self.first_dense_layers)
               + ffn_dense * self.first_dense_layers) / L
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(L * (attn + ffn + 2 * d) + emb + d)


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------


def _layer_params(key, cfg: LMConfig, moe_layer: bool) -> Dict:
    ks = split_keys(key, ["attn", "ffn", "n1", "n2"])
    if cfg.attn_kind == "mla":
        attn = mla_params(ks["attn"], cfg.d_model, cfg.n_heads,
                          cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                          cfg.v_head_dim, cfg.dtype)
    else:
        attn = gqa_params(ks["attn"], cfg.d_model, cfg.n_heads,
                          cfg.n_kv_heads, cfg.d_head, cfg.qkv_bias,
                          cfg.dtype)
    if moe_layer:
        ffn = moe_params(ks["ffn"], cfg.d_model, cfg.n_experts,
                         cfg.moe_d_ff, cfg.n_shared, cfg.dtype)
    else:
        ffn = swiglu_params(ks["ffn"], cfg.d_model, cfg.d_ff, cfg.dtype)
    return {"attn": attn, "ffn": ffn,
            "norm1": jnp.ones((cfg.d_model,), cfg.dtype),
            "norm2": jnp.ones((cfg.d_model,), cfg.dtype)}


def init_params(key, cfg: LMConfig) -> Dict:
    """Stacked-layer params. MoE models with leading dense layers keep two
    stacks (dense prefix + moe body) so each scans independently."""
    ks = split_keys(key, ["embed", "head", "layers", "final"])
    n_dense = cfg.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense
    params: Dict = {
        "embed": embed_init(ks["embed"], (cfg.vocab, cfg.d_model),
                            cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks["head"],
                                       (cfg.d_model, cfg.vocab), cfg.dtype)
    lk = jax.random.split(ks["layers"], cfg.n_layers)

    def stack(keys, moe_layer):
        layers = [_layer_params(k, cfg, moe_layer) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    if n_dense > 0:
        params["dense_layers"] = stack(lk[:n_dense], False)
    if n_moe > 0:
        params["moe_layers"] = stack(lk[n_dense:], True)
    return params


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _block(cfg: LMConfig, ctx: ShardCtx, moe_layer: bool, attn_impl: str):
    attn_fn = mla_attention if cfg.attn_kind == "mla" else gqa_attention

    def body(x, positions, lp, cache):
        h, new_cache = attn_fn(lp["attn"], rmsnorm(x, lp["norm1"],
                                                   cfg.norm_eps),
                               positions, cfg, ctx, cache=cache,
                               attn_impl=attn_impl)
        x = x + h
        hin = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if moe_layer:
            h, aux = moe_ffn(lp["ffn"], hin, ctx, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor)
        else:
            h, aux = swiglu(lp["ffn"], hin, ctx), jnp.zeros((), jnp.float32)
        return x + h, aux, new_cache

    return body


def forward(params: Dict, tokens: jax.Array, cfg: LMConfig,
            ctx: ShardCtx = ShardCtx(),
            positions: Optional[jax.Array] = None,
            caches: Optional[Dict] = None,
            attn_impl: str = "auto"
            ) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """tokens [B, T] -> (logits [B, T, V], aux_loss, updated caches)."""
    b, t = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = ctx.shard(x, ctx.dp, None, None)

    new_caches: Dict = {}
    aux_total = jnp.zeros((), jnp.float32)

    def run_stack(x, stack_name, moe_layer):
        nonlocal aux_total, new_caches
        lp = params[stack_name]
        body = _block(cfg, ctx, moe_layer, attn_impl)
        if caches is not None:
            # decode path: scan with cache carried per layer
            cache_stack = caches[stack_name]

            def step(carry, xs):
                h = carry
                layer_p, layer_cache = xs
                h2, aux, c2 = body(h, positions, layer_p, layer_cache)
                return h2, (aux, c2)

            x, (auxs, cs) = jax.lax.scan(step, x, (lp, cache_stack))
            new_caches[stack_name] = cs
        else:
            def step(carry, layer_p):
                h2, aux, _ = body(carry, positions, layer_p, None)
                return h2, aux

            if cfg.remat:
                step = jax.checkpoint(
                    step, policy=jax.checkpoint_policies.nothing_saveable)
            x, auxs = jax.lax.scan(step, x, lp)
        aux_total = aux_total + jnp.sum(auxs)
        return x

    if "dense_layers" in params:
        x = run_stack(x, "dense_layers", False)
    if "moe_layers" in params:
        x = run_stack(x, "moe_layers", True)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("btd,dv->btv", x, head)
    logits = ctx.shard(logits, ctx.dp, None, ctx.tp)
    return logits, aux_total, (new_caches if caches is not None else None)


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------


def loss_fn(params: Dict, batch: Dict, cfg: LMConfig,
            ctx: ShardCtx = ShardCtx(), attn_impl: str = "auto"):
    logits, aux, _ = forward(params, batch["tokens"], cfg, ctx,
                             attn_impl=attn_impl)
    ce = softmax_cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def init_caches(cfg: LMConfig, b: int, s_max: int) -> Dict:
    """Per-stack stacked caches matching init_params' layer stacks."""
    n_dense = cfg.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense

    def one(n):
        if cfg.attn_kind == "mla":
            c = init_mla_cache(b, s_max, cfg.kv_lora_rank, cfg.qk_rope_dim,
                               cfg.dtype)
        else:
            c = init_gqa_cache(b, s_max, cfg.n_kv_heads, cfg.d_head,
                               cfg.dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(
            x[None], (n,) + x.shape), c)

    out = {}
    if n_dense > 0:
        out["dense_layers"] = one(n_dense)
    if n_moe > 0:
        out["moe_layers"] = one(n_moe)
    return out


def decode_step(params: Dict, caches: Dict, tokens: jax.Array,
                position: jax.Array, cfg: LMConfig,
                ctx: ShardCtx = ShardCtx()) -> Tuple[jax.Array, Dict]:
    """One-token decode: tokens [B, 1], position scalar (cache length).

    The caches carry ``length`` themselves; ``position`` feeds RoPE.
    """
    b = tokens.shape[0]
    positions = jnp.broadcast_to(position, (b, 1))
    logits, _, new_caches = forward(params, tokens, cfg, ctx,
                                    positions=positions, caches=caches)
    return logits[:, -1], new_caches


def prefill_step(params: Dict, tokens: jax.Array, cfg: LMConfig,
                 ctx: ShardCtx = ShardCtx(), attn_impl: str = "auto"
                 ) -> jax.Array:
    """Prefill forward (logits only; cache population elided in the
    benchmark cell — the compute profile is the causal full-sequence pass)."""
    logits, _, _ = forward(params, tokens, cfg, ctx, attn_impl=attn_impl)
    return logits[:, -1]
