"""train package."""
