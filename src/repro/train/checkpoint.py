"""Fault-tolerant checkpointing with elastic re-sharding.

Design constraints (1000+-node deployments):
  * **atomic**: write to ``<dir>/.tmp-<step>`` then ``os.replace`` — a crash
    mid-write never corrupts the latest checkpoint;
  * **logical layout**: checkpoints store the *unsharded* logical arrays
    (host-gathered), so a restart may resume on a *different* mesh — the
    restore path re-shards every leaf to the live mesh's NamedSharding
    (elastic scaling after node loss);
  * **keep-K** retention with best-effort cleanup;
  * single-writer discipline: in a multi-controller deployment only
    process 0 writes (``should_write``), all processes restore.

Format: one ``.npz`` per checkpoint (flattened pytree paths as keys) + a
JSON sidecar with step/metadata. No external dependencies.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "//"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    should_write: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Any,
             metadata: Optional[Dict] = None) -> str:
        if not self.should_write:
            return ""
        flat = _flatten(state)
        tmp = os.path.join(self.directory, f".tmp-{step}")
        final = os.path.join(self.directory, f"ckpt-{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        meta = {"step": step, "time": time.time(),
                "n_leaves": len(flat)}
        meta.update(metadata or {})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        cks = self.list_steps()
        for step in cks[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory,
                                       f"ckpt-{step:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt-(\d{8})", name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Any:
        """Load ``step`` into the structure of ``template``; if
        ``shardings`` (pytree of NamedSharding) is given, every leaf is
        device_put to it — this is the elastic re-shard path."""
        path = os.path.join(self.directory, f"ckpt-{step:08d}", "state.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_or_init(self, init_fn: Callable[[], Any],
                        shardings: Any = None) -> Tuple[Any, int]:
        """Restart-after-failure entry point: returns (state, start_step)."""
        step = self.latest_step()
        if step is None:
            return init_fn(), 0
        template = jax.eval_shape(init_fn)
        return self.restore(step, template, shardings), step
