"""Training loop with checkpoint/restart, failure injection and optional
manual-DP gradient compression.

Two execution modes:

* ``pjit`` (default): the step is jit'd with parameter/optimizer shardings;
  XLA inserts all collectives. This is the mode the multi-pod dry-run
  lowers.
* ``manual_dp``: the step runs under shard_map over the DP axis with an
  explicit gradient psum — required to exercise int8 gradient compression
  with error feedback (distributed/compression.py).

Fault tolerance: the loop checkpoints every ``ckpt_every`` steps through
:class:`~repro.train.checkpoint.CheckpointManager` and starts from
``restore_or_init`` — killing the process at any step and rerunning the
same command resumes bit-exactly (tests/test_train.py does exactly that,
plus an elastic-resharding restart on a different device count).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import shard_map
from ..distributed.compression import compressed_psum, plain_psum_mean
from .checkpoint import CheckpointManager
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


@dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    fail_at_step: Optional[int] = None        # failure injection (tests)
    grad_compression: Optional[str] = None    # None | "int8" (manual_dp)


def run_training(loss_fn: Callable,
                 init_params_fn: Callable[[], Any],
                 batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 opt_cfg: AdamWConfig,
                 loop_cfg: TrainLoopConfig,
                 ckpt: Optional[CheckpointManager] = None,
                 shardings: Any = None,
                 mesh=None,
                 dp_axis: Optional[str] = None) -> Dict[str, list]:
    """Generic driver used by the examples and the restart tests.

    ``loss_fn(params, batch) -> (loss, metrics)``.
    Returns the metric history (host floats).
    """

    def init_state():
        params = init_params_fn()
        return {"params": params, "opt": adamw_init(params)}

    start_step = 0
    if ckpt is not None:
        state, start_step = ckpt.restore_or_init(init_state, shardings)
    else:
        state = init_state()

    use_manual_dp = (loop_cfg.grad_compression is not None
                     and mesh is not None and dp_axis is not None)

    if use_manual_dp:
        from jax.sharding import PartitionSpec as P

        err0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            state["params"])
        if "err" not in state:
            state["err"] = err0

        def local_step(params, opt, err, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if loop_cfg.grad_compression == "int8":
                grads, err = compressed_psum(grads, dp_axis, err)
            else:
                grads = plain_psum_mean(grads, dp_axis)
            new_params, new_opt, om = adamw_update(opt_cfg, grads, opt,
                                                   params)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss_total"] = jax.lax.pmean(loss, dp_axis)
            return new_params, new_opt, err, metrics

        rep = jax.tree.map(lambda _: P(), state["params"])
        step_fn = jax.jit(shard_map(
            local_step, mesh=mesh,
            in_specs=(rep, jax.tree.map(lambda _: P(), state["opt"]),
                      rep, P(dp_axis)),
            out_specs=(rep, jax.tree.map(lambda _: P(), state["opt"]),
                       rep, P()),
            check_vma=False))
    else:
        def full_step(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            new_params, new_opt, om = adamw_update(opt_cfg, grads, opt,
                                                   params)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss_total"] = loss
            return new_params, new_opt, metrics

        step_fn = jax.jit(full_step)

    history: Dict[str, list] = {"step": [], "loss": []}
    t0 = time.time()
    for step in range(start_step, loop_cfg.steps):
        if loop_cfg.fail_at_step is not None and step == loop_cfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        if use_manual_dp:
            p, o, e, metrics = step_fn(state["params"], state["opt"],
                                       state["err"], batch)
            state = {"params": p, "opt": o, "err": e}
        else:
            p, o, metrics = step_fn(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o}
        if ckpt is not None and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(step + 1, state)
        if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
            loss = float(metrics["loss_total"])
            history["step"].append(step + 1)
            history["loss"].append(loss)
            print(f"step {step + 1:5d} loss {loss:.4f} "
                  f"({(time.time() - t0):.1f}s)")
    history["final_state"] = state     # type: ignore
    return history
