"""AdamW + schedules, dependency-free (pure pytree transforms).

The optimizer state is a pytree mirroring params (m, v) + a step counter, so
it shards exactly like the parameters (FSDP: opt state inherits the param
PartitionSpec — see launch/shardings.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), g


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"lr": lr, "grad_norm": gnorm}


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    donate: bool = True):
    """loss_fn(params, batch) -> (loss, metrics). Returns jit-able
    step(params, opt_state, batch) -> (params', opt_state', metrics)."""

    def step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_params, new_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_total"] = loss
        return new_params, new_state, metrics

    return step
