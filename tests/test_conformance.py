"""Cross-engine conformance through the unified Executor API.

Every backend (ref / jax / dist) runs the same plan through the same
driver, so match counts must agree exactly — the correctness bar for
distributed subgraph matching is exact agreement, not approximation. Also
unit-tests the adaptive task-splitting driver itself: forced ENU overflow
must re-chunk the offending start batch (smaller frontiers, same
capacities) and never drop or duplicate a match.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.executor import (ceil_div, ChunkResult, ExecStats, Executor,
                                 ExecutorBackend, ExecutorConfig, drive,
                                 make_executor, plan_enu_count,
                                 split_id_batch)
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.core.ref_engine import enumerate_matches_brute
from repro.core.symmetry import symmetry_breaking_constraints
from repro.graph.generate import erdos_renyi, powerlaw

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# triangle, 4-cycle, 4-clique, 5-vertex house, 5-path, 5-cycle
PATTERNS = ["triangle", "square", "clique4", "house", "path5", "cycle5"]
GRAPHS = {
    "er": erdos_renyi(64, 256, seed=11),
    "pl": powerlaw(64, 4, seed=12),
}


_BRUTE_CACHE = {}


def brute_count(pname, g):
    key = (pname, id(g))
    if key not in _BRUTE_CACHE:
        p = get_pattern(pname)
        _BRUTE_CACHE[key] = len(enumerate_matches_brute(
            p, g, symmetry_breaking_constraints(p)))
    return _BRUTE_CACHE[key]


# --------------------------------------------------------------------------
# ref == jax on every pattern x graph (single device, in process)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pname", PATTERNS)
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_ref_jax_conformance_unified_api(pname, gname):
    g = GRAPHS[gname]
    p = get_pattern(pname)
    plan = generate_best_plan(p, g.stats())
    ref = make_executor("ref").run(plan, g, batch=32)
    jx = make_executor("jax").run(plan, g, batch=32)
    want = brute_count(pname, g)
    assert ref.count == jx.count == want, (pname, gname)


# --------------------------------------------------------------------------
# jax-gpu (fused gather+intersect fetch path) == brute on every pattern x
# graph, with the fused Pallas kernel forced on in interpret mode — the
# only CI coverage the accelerator fetch path gets on the CPU container
# (ISSUE 5 acceptance bar). The fused kernel really fires here: triangle /
# square / clique4 / house all carry single-use DBQ operands
# (engine_jax.classify_fusable_dbqs); path5 / cycle5 pin the all-
# materialized degenerate case.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pname", PATTERNS)
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_jax_gpu_fused_conformance(pname, gname, monkeypatch):
    monkeypatch.setenv("REPRO_GATHER_INTERSECT_IMPL", "pallas-interpret")
    monkeypatch.delenv("REPRO_FUSED_FETCH", raising=False)
    g = GRAPHS[gname]
    p = get_pattern(pname)
    plan = generate_best_plan(p, g.stats())
    st = make_executor("jax-gpu").run(plan, g, batch=32)
    assert st.count == brute_count(pname, g), (pname, gname)
    assert st.extras["fused_fetch"] is True


def test_jax_gpu_fused_forced_overflow_match_set_exact():
    """Lazy DBQ id columns must survive re-chunking: the adaptive driver's
    split/escalate path with the fused kernel on neither drops nor
    duplicates matches."""
    p = get_pattern("clique4")
    g = GRAPHS["er"]
    plan = generate_best_plan(p, g.stats())
    n_enu = plan_enu_count(plan)
    ref = make_executor("ref").run(plan, g, batch=32, collect_matches=True)
    gpu = make_executor("jax-gpu", gather_intersect_impl="interpret").run(
        plan, g, batch=16, caps=[8] * n_enu, max_retries=12,
        collect_matches=True)
    got = {tuple(int(x) for x in row) for row in gpu.matches}
    want = {tuple(int(x) for x in row) for row in ref.matches}
    assert got == want
    assert len(gpu.matches) == len(got)
    assert gpu.chunks_split > 0


# --------------------------------------------------------------------------
# oocache == brute on every pattern x graph, with the device cache bounded
# below 25% of the graph's rows (ISSUE 3 acceptance bar): the host-RAM
# store + bounded device cache must be a drop-in engine, not an
# approximation
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pname", PATTERNS)
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_oocache_conformance_bounded_device_cache(pname, gname):
    g = GRAPHS[gname]
    p = get_pattern(pname)
    plan = generate_best_plan(p, g.stats())
    cap = max(1, int(g.n * 0.12))
    hot = max(1, int(g.n * 0.04))
    st = make_executor("oocache", cache_rows=cap, hot=hot).run(
        plan, g, batch=32)
    assert st.count == brute_count(pname, g), (pname, gname)
    # device residency — slab + both prefetch staging buffers + pinned
    # hot + sentinel, i.e. the whole footprint — under 25% of rows, and
    # the out-of-core path actually exercised (cold fetches happened)
    assert st.extras["device_resident_rows"] < 0.25 * (g.n + 1)
    assert st.extras["cache"]["cold_rows"] > 0


# --------------------------------------------------------------------------
# ref == jax == dist (8 forced host devices, one subprocess for all runs)
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_three_engine_conformance_exact():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import json
        from repro.core.executor import make_executor
        from repro.core.pattern import get_pattern
        from repro.core.plangen import generate_best_plan
        from repro.core.ref_engine import enumerate_matches_brute
        from repro.core.symmetry import symmetry_breaking_constraints
        from repro.graph.generate import powerlaw
        g = powerlaw(100, 4, seed=4)
        res = {}
        for pname in ("triangle", "square", "clique4", "house"):
            P = get_pattern(pname)
            plan = generate_best_plan(P, g.stats())
            brute = len(enumerate_matches_brute(
                P, g, symmetry_breaking_constraints(P)))
            ref = make_executor("ref").run(plan, g, batch=32).count
            jx = make_executor("jax").run(plan, g, batch=32).count
            ds = make_executor("dist", hot=8, rebalance=True).run(
                plan, g, batch=64).count
            res[pname] = dict(brute=brute, ref=ref, jax=jx, dist=ds)
        print(json.dumps(res))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert set(res) == {"triangle", "square", "clique4", "house"}
    for pname, r in res.items():
        assert r["ref"] == r["jax"] == r["dist"] == r["brute"], (pname, r)


# --------------------------------------------------------------------------
# Adaptive task splitting: forced ENU overflow re-chunks, never drops
# --------------------------------------------------------------------------


def test_forced_overflow_rechunks_and_stays_exact():
    p = get_pattern("house")
    g = GRAPHS["pl"]
    plan = generate_best_plan(p, g.stats())
    n_enu = plan_enu_count(plan)
    want = brute_count("house", g)
    # capacities far too small for a 16-start batch: the driver must split
    st = make_executor("jax").run(plan, g, batch=16, caps=[8] * n_enu,
                                  max_retries=12)
    assert st.count == want
    assert st.chunks_split > 0          # it re-chunked (did not just pad)
    assert st.chunks_run > st.chunks_split


def test_forced_overflow_match_set_exact_not_just_count():
    """Re-chunking must neither drop nor duplicate matches."""
    p = get_pattern("clique4")
    g = GRAPHS["er"]
    plan = generate_best_plan(p, g.stats())
    n_enu = plan_enu_count(plan)
    ref = make_executor("ref").run(plan, g, batch=32, collect_matches=True)
    jx = make_executor("jax").run(plan, g, batch=16, caps=[8] * n_enu,
                                  max_retries=12, collect_matches=True)
    got = {tuple(int(x) for x in row) for row in jx.matches}
    want = {tuple(int(x) for x in row) for row in ref.matches}
    assert got == want
    assert len(jx.matches) == len(got)  # no duplicates emitted


def test_overflow_disables_split_falls_back_to_caps():
    """adaptive_split=False reproduces the legacy capacity-doubling path."""
    p = get_pattern("house")
    g = GRAPHS["pl"]
    plan = generate_best_plan(p, g.stats())
    n_enu = plan_enu_count(plan)
    want = brute_count("house", g)
    st = make_executor("jax").run(plan, g, batch=16, caps=[8] * n_enu,
                                  max_retries=12, adaptive_split=False)
    assert st.count == want
    assert st.chunks_split == 0 and st.chunks_retried > 0


# --------------------------------------------------------------------------
# Driver unit tests on a deterministic fake backend (no jax involved)
# --------------------------------------------------------------------------


class FakeBackend(ExecutorBackend):
    """Each valid start yields exactly one match; a chunk 'overflows'
    whenever its demand (valid starts x fanout) exceeds caps[0]."""

    name = "fake"
    granularity = 1

    def __init__(self, n, fanout=1):
        self.n = n
        self.fanout = fanout
        self.seen = []                     # ids from successful chunks
        self.runs = 0

    def prepare(self, plan, source, config):
        self.sentinel = self.n

    def _n_starts(self):
        return self.n

    def initial_caps(self, config):
        return tuple(config.caps) if config.caps else (1,)

    def run_chunk(self, ids, valid, universe_chunk, caps):
        self.runs += 1
        nv = int(valid.sum())
        demand = nv * self.fanout
        if demand > caps[0]:
            return ChunkResult(count=0, overflow=demand - caps[0])
        self.seen.extend(int(v) for v in ids[valid])
        return ChunkResult(count=nv)


def test_driver_splits_to_fit_and_loses_nothing():
    be = FakeBackend(n=37)
    st = drive(be, None, None, ExecutorConfig(batch=16, caps=(2,)))
    assert st.count == 37
    assert sorted(be.seen) == list(range(37))      # every start exactly once
    assert st.chunks_split > 0
    assert st.chunks_retried == 0      # splitting alone fits caps=2


def test_driver_grows_caps_only_when_unsplittable():
    # fanout 4 with caps=1: even a single start overflows until caps reach 4
    be = FakeBackend(n=5, fanout=4)
    st = drive(be, None, None, ExecutorConfig(batch=4, caps=(1,)))
    assert st.count == 5
    assert sorted(be.seen) == list(range(5))
    assert st.chunks_retried > 0       # capacity-doubling was required
    assert st.chunks_split > 0         # after splitting down to singletons


def test_driver_raises_after_retry_budget():
    class AlwaysOverflow(FakeBackend):
        def run_chunk(self, ids, valid, universe_chunk, caps):
            return ChunkResult(count=0, overflow=1)

    be = AlwaysOverflow(n=4)
    with pytest.raises(RuntimeError, match="overflowed"):
        drive(be, None, None,
              ExecutorConfig(batch=4, caps=(1,), max_retries=3))


def test_split_id_batch_partitions_valid_ids():
    ids = np.arange(16, dtype=np.int32)
    valid = (ids % 3 != 0)
    halves = split_id_batch(ids, valid, granularity=1, sentinel=99)
    assert halves is not None and len(halves) == 2
    got = []
    for h_ids, h_valid in halves:
        assert h_ids.shape == (8,) and h_valid.shape == (8,)
        got.extend(int(v) for v in h_ids[h_valid])
    assert sorted(got) == sorted(int(v) for v in ids[valid])


def test_split_id_batch_odd_full_batch_drops_nothing():
    # B=5 all valid: halves get ceil(5/2)=3 and 2 ids — shape must fit 3
    ids = np.arange(5, dtype=np.int32)
    valid = np.ones(5, bool)
    halves = split_id_batch(ids, valid, granularity=1, sentinel=99)
    got = sorted(int(v) for h_ids, h_valid in halves
                 for v in h_ids[h_valid])
    assert got == list(range(5))


def test_driver_exact_with_odd_batch_under_overflow():
    be = FakeBackend(n=23)
    st = drive(be, None, None, ExecutorConfig(batch=7, caps=(2,)))
    assert st.count == 23
    assert sorted(be.seen) == list(range(23))


class MultipleBackend(FakeBackend):
    """FakeBackend advertising a mesh-style cap multiple; records every
    caps tuple the driver hands it."""

    cap_multiple = 8

    def __init__(self, n, fanout=1):
        super().__init__(n, fanout=fanout)
        self.caps_seen = []

    def run_chunk(self, ids, valid, universe_chunk, caps):
        self.caps_seen.append(tuple(caps))
        return super().run_chunk(ids, valid, universe_chunk, caps)


def test_driver_rounds_caps_to_backend_multiple():
    """The driver — not the backend — must keep every caps tuple it hands
    out divisible by cap_multiple (the rebalancer's ``cap % mesh size``
    contract): initial caps AND capacity-doubled ones. Regression for the
    `assert cap % n_shards == 0` crash on odd user/degree-derived caps."""
    be = MultipleBackend(n=20, fanout=4)
    st = drive(be, None, None, ExecutorConfig(batch=4, caps=(7,)))
    assert st.count == 20
    assert sorted(be.seen) == list(range(20))
    assert be.caps_seen and all(c % 8 == 0
                                for caps in be.caps_seen for c in caps)
    # odd initial caps rounded up (7 -> 8), not truncated down to 0
    assert min(c for caps in be.caps_seen for c in caps) >= 8


def test_driver_rounds_escalated_caps_to_multiple():
    class OddGrowth(MultipleBackend):
        def grow_caps(self, caps):
            return tuple(c * 2 + 1 for c in caps)   # always odd

    be = OddGrowth(n=3, fanout=40)
    st = drive(be, None, None,
               ExecutorConfig(batch=1, caps=(1,), max_retries=8))
    assert st.count == 3
    assert st.chunks_retried > 0
    assert all(c % 8 == 0 for caps in be.caps_seen for c in caps)


def test_split_id_batch_respects_granularity_and_floor():
    ids = np.arange(16, dtype=np.int32)
    valid = np.ones(16, bool)
    halves = split_id_batch(ids, valid, granularity=8, sentinel=99)
    assert all(h[0].shape == (8,) for h in halves)
    # a mesh-wide batch (B == granularity) cannot shrink further
    assert split_id_batch(ids[:8], valid[:8], granularity=8,
                          sentinel=99) is None
    assert split_id_batch(ids[:1], valid[:1], granularity=1,
                          sentinel=99) is None


def test_ceil_div_pins_half_computation():
    """The readable ceil-div form must reproduce the original
    quadruple-negation ``half`` expression bit for bit."""
    for B in range(2, 70):
        for granularity in (1, 2, 3, 4, 8, 16):
            legacy = -(-(-(-B // 2)) // granularity) * granularity
            assert ceil_div(ceil_div(B, 2), granularity) * granularity \
                == legacy, (B, granularity)
    assert ceil_div(0, 4) == 0
    assert ceil_div(1, 4) == 1
    assert ceil_div(8, 4) == 2
    assert ceil_div(9, 4) == 3


# --------------------------------------------------------------------------
# Streaming conformance: sbenu-jax == SBenuRefEngine == snapshot diff oracle
# over randomized insert/delete update streams
# --------------------------------------------------------------------------


SBENU_PATTERNS = ["dtoy", "q1'", "q2'", "q3'", "q5'"]


@pytest.mark.parametrize("pname", SBENU_PATTERNS)
def test_sbenu_jax_stream_conformance(pname):
    """ΔR_t^+ / ΔR_t^- must agree exactly across the vectorized engine,
    the interpreter, and the brute-force snapshot diff, on a randomized
    stream with both insertions and deletions."""
    from repro.core.estimate import GraphStats
    from repro.core.executor import SBenuJaxBackend
    from repro.core.sbenu import (generate_best_sbenu_plans, run_timestep,
                                  snapshot_diff_oracle)
    from repro.graph.dynamic import SnapshotStore
    from repro.graph.generate import edge_stream

    p = get_pattern(pname)
    g0, batches = edge_stream(n=24, m_init=110, steps=3, batch=24,
                              seed=17, delete_frac=0.4)
    store_jax = SnapshotStore(g0)
    store_ref = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(p, GraphStats(24, 110,
                                                    delta_edges=24))
    backend = SBenuJaxBackend()          # reused: compiled once per stream
    for batch in batches:
        want_p, want_m = snapshot_diff_oracle(p, store_jax, batch)
        assert any(op == "-" for op, _, _ in batch)   # deletions exercised
        jp, jm, _ = run_timestep(p, plans, store_jax, batch,
                                 backend=backend, chunk=16)
        rp, rm, _ = run_timestep(p, plans, store_ref, batch, engine="ref")
        assert jp == rp == want_p
        assert jm == rm == want_m


def test_sbenu_jax_forced_overflow_stays_exact():
    """Tiny capacities force the adaptive driver to re-split delta chunks;
    the match sets must still be exact."""
    from repro.core.estimate import GraphStats
    from repro.core.executor import ExecutorConfig, SBenuJaxBackend, drive
    from repro.core.sbenu import (generate_best_sbenu_plans,
                                  snapshot_diff_oracle)
    from repro.graph.dynamic import SnapshotStore
    from repro.graph.generate import edge_stream

    p = get_pattern("q1'")
    g0, batches = edge_stream(n=40, m_init=250, steps=1, batch=40, seed=5)
    store = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(p, GraphStats(40, 250,
                                                    delta_edges=40))
    want_p, want_m = snapshot_diff_oracle(p, store, batches[0])
    store.begin_step(batches[0])
    st = drive(SBenuJaxBackend(), plans, store,
               ExecutorConfig(batch=32, caps=[4, 4, 4], max_retries=12,
                              collect_matches=True))
    store.end_step()
    assert st.extras["delta_plus"] == want_p
    assert st.extras["delta_minus"] == want_m
    assert st.chunks_split > 0


# --------------------------------------------------------------------------
# Distributed streaming conformance: sbenu-dist == interpreter == oracle.
# In-process runs use the default single device (S=1 makes the typed-DBQ
# all_to_alls local exchanges — fast tier); the 8-way matrix including the
# rebalancer + forced-overflow re-split runs in a subprocess (slow tier).
# --------------------------------------------------------------------------


def test_sbenu_dist_stream_conformance_single_device():
    from repro.core.estimate import GraphStats
    from repro.core.executor import SBenuDistBackend
    from repro.core.sbenu import (generate_best_sbenu_plans, run_timestep,
                                  snapshot_diff_oracle)
    from repro.graph.dynamic import SnapshotStore, stream_width_floors
    from repro.graph.generate import edge_stream

    for pname in ("dtoy", "q1'"):
        p = get_pattern(pname)
        g0, batches = edge_stream(n=24, m_init=110, steps=2, batch=24,
                                  seed=17, delete_frac=0.4)
        store = SnapshotStore(g0)
        plans = generate_best_sbenu_plans(p, GraphStats(24, 110,
                                                        delta_edges=24))
        d, dd = stream_width_floors(g0, batches)
        # widths pinned over the stream: the sharded blocks stay resident
        backend = SBenuDistBackend(hot=4, d_min=d, delta_d_min=dd)
        for batch in batches:
            want_p, want_m = snapshot_diff_oracle(p, store, batch)
            assert any(op == "-" for op, _, _ in batch)
            dp, dm, _ = run_timestep(p, plans, store, batch,
                                     backend=backend, chunk=16)
            assert dp == want_p and dm == want_m, pname
        # the sharded snapshot stayed resident (one initial build only)
        assert backend.dstore.rebuilds == 1


@pytest.mark.slow
def test_sbenu_dist_eight_way_stream_matrix():
    """The full randomized-stream matrix on an 8-way host mesh, with hot
    rows + the frontier rebalancer on, plus the forced-overflow re-split
    case with odd caps — the regression for the driver handing the
    rebalancer capacities not divisible by the mesh size
    (`assert cap % n_shards == 0`, core/engine_dist.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import json
        from repro.core.estimate import GraphStats
        from repro.core.pattern import get_pattern
        from repro.core.executor import (ExecutorConfig, SBenuDistBackend,
                                         drive)
        from repro.core.sbenu import (generate_best_sbenu_plans,
                                      run_timestep, snapshot_diff_oracle)
        from repro.graph.dynamic import SnapshotStore
        from repro.graph.generate import edge_stream

        res = {}
        for pname in ("dtoy", "q1'", "q2'", "q3'", "q5'"):
            p = get_pattern(pname)
            g0, batches = edge_stream(n=24, m_init=110, steps=2, batch=24,
                                      seed=17, delete_frac=0.4)
            store = SnapshotStore(g0)
            store_ref = SnapshotStore(g0)
            store_jax = SnapshotStore(g0)
            plans = generate_best_sbenu_plans(
                p, GraphStats(24, 110, delta_edges=24))
            backend = SBenuDistBackend(hot=4, rebalance=True)
            ok = True
            for batch in batches:
                want_p, want_m = snapshot_diff_oracle(p, store, batch)
                dp, dm, _ = run_timestep(p, plans, store, batch,
                                         backend=backend, chunk=16)
                rp, rm, _ = run_timestep(p, plans, store_ref, batch,
                                         engine="ref")
                jp, jm, _ = run_timestep(p, plans, store_jax, batch,
                                         engine="sbenu-jax", chunk=16)
                ok = ok and dp == rp == jp == want_p
                ok = ok and dm == rm == jm == want_m
            res[pname] = ok

        # forced overflow with ODD caps on the 8-way mesh: the driver must
        # round to the mesh multiple (previously: rebalancer assert crash)
        p = get_pattern("q1'")
        g0, batches = edge_stream(n=40, m_init=250, steps=1, batch=40,
                                  seed=5)
        store = SnapshotStore(g0)
        plans = generate_best_sbenu_plans(
            p, GraphStats(40, 250, delta_edges=40))
        want_p, want_m = snapshot_diff_oracle(p, store, batches[0])
        store.begin_step(batches[0])
        st = drive(SBenuDistBackend(rebalance=True), plans, store,
                   ExecutorConfig(batch=32, caps=[7, 7, 7],
                                  max_retries=12, collect_matches=True))
        store.end_step()
        res["odd_caps_exact"] = (st.extras["delta_plus"] == want_p
                                 and st.extras["delta_minus"] == want_m)
        # tiny even caps actually exercise the mesh-wide re-split path
        store2 = SnapshotStore(g0)
        want_p2, want_m2 = snapshot_diff_oracle(p, store2, batches[0])
        store2.begin_step(batches[0])
        st2 = drive(SBenuDistBackend(), plans, store2,
                    ExecutorConfig(batch=32, caps=[2, 2, 2],
                                   max_retries=12, collect_matches=True))
        store2.end_step()
        res["overflow_exact"] = (st2.extras["delta_plus"] == want_p2
                                 and st2.extras["delta_minus"] == want_m2)
        res["overflow_split"] = int(st2.chunks_split)
        print(json.dumps(res))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for pname in ("dtoy", "q1'", "q2'", "q3'", "q5'"):
        assert res[pname], pname
    assert res["odd_caps_exact"]
    assert res["overflow_exact"]
    assert res["overflow_split"] > 0


# --------------------------------------------------------------------------
# The Pallas INT path on CPU: REPRO_INTERSECT_IMPL=pallas-interpret routes
# every auto intersect through the Pallas kernel in interpret mode — both
# the static frontier engine and the streaming delta engine must stay
# exact (this is the only CI coverage the TPU kernel dispatch path gets)
# --------------------------------------------------------------------------


def test_intersect_pallas_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_INTERSECT_IMPL", "pallas-interpret")
    from repro.core.engine_sbenu_jax import _resolve_intersect_impl
    from repro.kernels.dispatch import resolve_impl
    assert _resolve_intersect_impl("auto") == "interpret"
    assert _resolve_intersect_impl("binary") == "binary"   # explicit wins
    # the streaming resolver is a veneer over the shared dispatch registry
    assert resolve_impl("intersect") == "interpret"
    monkeypatch.delenv("REPRO_INTERSECT_IMPL")
    assert _resolve_intersect_impl("auto") == "binary"     # CPU default
    # the literal env value "auto" is a reset, not an override: the
    # streaming engine must keep its binary-probe CPU default
    monkeypatch.setenv("REPRO_INTERSECT_IMPL", "auto")
    assert _resolve_intersect_impl("auto") == "binary"
    monkeypatch.setenv("REPRO_INTERSECT_IMPL", "pallas-interpret")

    # static path (engine_jax -> kernels.ops dispatch)
    g = GRAPHS["er"]
    p = get_pattern("triangle")
    plan = generate_best_plan(p, g.stats())
    st = make_executor("jax").run(plan, g, batch=32)
    assert st.count == brute_count("triangle", g)

    # streaming path (mixed-width intersects: delta rows x adjacency rows)
    from repro.core.estimate import GraphStats
    from repro.core.executor import SBenuJaxBackend
    from repro.core.sbenu import (generate_best_sbenu_plans, run_timestep,
                                  snapshot_diff_oracle)
    from repro.graph.dynamic import SnapshotStore
    from repro.graph.generate import edge_stream
    sp = get_pattern("q1'")
    g0, batches = edge_stream(n=24, m_init=110, steps=1, batch=20, seed=3)
    store = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(sp, GraphStats(24, 110,
                                                     delta_edges=20))
    want_p, want_m = snapshot_diff_oracle(sp, store, batches[0])
    dp, dm, _ = run_timestep(sp, plans, store, batches[0],
                             backend=SBenuJaxBackend(), chunk=16)
    assert dp == want_p and dm == want_m
