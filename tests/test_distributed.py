"""Distribution tests: run in subprocesses with forced host device counts
(the main pytest process must keep the default single device — dry-run
policy). Covers the rowstore all_to_all fetch, distributed enumeration
(exactness, hot rows, rebalancing), and int8-compressed gradient psum."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_distributed_enumeration_exact_with_all_features():
    out = run_sub("""
        import json, numpy as np
        from repro.core.pattern import get_pattern
        from repro.core.plangen import generate_best_plan
        from repro.core.ref_engine import enumerate_matches_brute
        from repro.core.engine_dist import enumerate_distributed
        from repro.core.symmetry import symmetry_breaking_constraints
        from repro.graph.generate import powerlaw
        g = powerlaw(120, 4, seed=4)
        res = {}
        for pname in ("triangle", "chordal-square", "house"):
            P = get_pattern(pname)
            plan = generate_best_plan(P, g.stats())
            brute = len(enumerate_matches_brute(
                P, g, symmetry_breaking_constraints(P)))
            st = enumerate_distributed(plan, g, batch_per_shard=16,
                                       hot=16, rebalance=True)
            st0 = enumerate_distributed(plan, g, batch_per_shard=16)
            res[pname] = dict(
                brute=brute, dist=st.count, plain=st0.count,
                cold_hot=st.cold_rows_fetched,
                cold_plain=st0.cold_rows_fetched,
                skew_reb=int(st.per_shard_level_sizes[-1].max()
                             - st.per_shard_level_sizes[-1].min())
                if len(st.per_shard_level_sizes) else 0)
        print(json.dumps(res))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    for pname, r in res.items():
        assert r["dist"] == r["brute"] == r["plain"], (pname, r)
        # hot-row replication strictly reduces remote traffic
        assert r["cold_hot"] <= r["cold_plain"], (pname, r)


@pytest.mark.slow
def test_rowstore_fetch_unit():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp, json
        from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
        from repro.compat import shard_map
        from repro.distributed.rowstore import (build_row_shards,
                                                make_distributed_fetch)
        from repro.graph.generate import erdos_renyi
        g = erdos_renyi(100, 300, seed=0)
        S = 8
        shards_np, hot_np, spec = build_row_shards(g, S, hot=8)
        mesh = Mesh(np.array(jax.devices()), ("s",))
        fetch = make_distributed_fetch(spec, "s", req_cap=32)
        B = 16
        rng = np.random.default_rng(0)
        ids = rng.integers(0, g.n, size=(S, B)).astype(np.int32)

        def local(shards, hot, ids):
            rows, cold, drops = fetch(ids[0], shards[0], hot)
            return rows[None], cold[None], drops[None]

        f = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(P("s", None, None), P(None, None), P("s", None)),
            out_specs=(P("s", None, None), P("s"), P("s")),
            check_vma=False))
        rows, cold, drops = f(shards_np, hot_np, ids)
        rows = np.asarray(rows).reshape(S * B, spec.d)
        want = np.concatenate([shards_np.reshape(-1, spec.d)])
        ok = True
        flat_ids = ids.reshape(-1)
        for i, v in enumerate(flat_ids):
            exp = want[v]
            ok &= np.array_equal(rows[i], exp)
        print(json.dumps({"ok": bool(ok), "drops": int(np.sum(drops)),
                          "cold": int(np.sum(cold))}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["drops"] == 0


# --------------------------------------------------------------------------
# DistributedRowStore hot-row boundary (in-process, single-device mesh:
# S=1 makes the all_to_all a local exchange, so this stays in the fast
# tier). The fetch must match the unsharded padded-adjacency oracle with
# ids exactly at n_hot_lo, with zero hot rows, and with every row hot.
# --------------------------------------------------------------------------


def _fetch_rows(g, hot, ids, req_cap=64):
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    from repro.distributed.rowstore import (build_row_shards,
                                            make_distributed_fetch)
    import numpy as np
    shards_np, hot_np, spec = build_row_shards(g, 1, hot=hot)
    mesh = Mesh(np.array(jax.devices()[:1]), ("s",))
    fetch = make_distributed_fetch(spec, "s", req_cap=req_cap)

    def local(shards, hot_rows, ids):
        rows, cold, drops = fetch(ids[0], shards[0], hot_rows)
        return rows[None], cold[None], drops[None]

    f = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P("s", None, None), P(None, None), P("s", None)),
        out_specs=(P("s", None, None), P("s"), P("s")),
        check_vma=False))
    rows, cold, drops = f(shards_np, hot_np, ids[None].astype(np.int32))
    import numpy as _np
    oracle = shards_np.reshape(-1, spec.d)[:spec.n + 1]
    return (_np.asarray(rows)[0], int(_np.sum(_np.asarray(cold))),
            int(_np.sum(_np.asarray(drops))), spec, oracle)


@pytest.mark.parametrize("hot", [0, 8, 100])   # zero / partial / all hot
def test_rowstore_hot_boundary_matches_unsharded_oracle(hot):
    import numpy as np
    from repro.graph.generate import erdos_renyi
    g = erdos_renyi(100, 300, seed=0)
    n_hot_lo = g.n - min(hot, g.n)
    # ids straddling the boundary: n_hot_lo - 1 (cold side), n_hot_lo
    # (first hot row), n_hot_lo + 1, plus extremes and the sentinel
    cand = [0, 1, n_hot_lo - 1, n_hot_lo, n_hot_lo + 1, g.n - 1, g.n]
    ids = np.array([i for i in cand if 0 <= i <= g.n], np.int64)
    ids = np.pad(ids, (0, 16 - ids.size), constant_values=g.n)
    rows, cold, drops, spec, oracle = _fetch_rows(g, hot, ids)
    assert drops == 0
    assert spec.hot == min(hot, g.n)
    for i, v in enumerate(ids):
        np.testing.assert_array_equal(rows[i], oracle[v], err_msg=str(v))
    # hot rows are served locally: they never count as cold traffic
    want_cold = len({int(v) for v in ids if v < n_hot_lo})
    assert cold == want_cold


def test_rowstore_all_hot_serves_everything_locally():
    import numpy as np
    from repro.graph.generate import powerlaw
    g = powerlaw(60, 3, seed=5)
    rng = np.random.default_rng(2)
    ids = rng.integers(0, g.n + 1, size=32).astype(np.int64)
    rows, cold, drops, spec, oracle = _fetch_rows(g, hot=g.n, ids=ids)
    assert cold == 0 and drops == 0       # every row replicated
    for i, v in enumerate(ids):
        np.testing.assert_array_equal(rows[i], oracle[v])


def test_rowstore_zero_hot_all_requests_remote():
    import numpy as np
    from repro.graph.generate import erdos_renyi
    g = erdos_renyi(50, 150, seed=3)
    ids = np.arange(16, dtype=np.int64)
    rows, cold, drops, spec, oracle = _fetch_rows(g, hot=0, ids=ids)
    assert drops == 0
    assert cold == 16                     # no replication: all cold
    for i, v in enumerate(ids):
        np.testing.assert_array_equal(rows[i], oracle[v])


@pytest.mark.slow
def test_int8_compressed_psum_error_feedback():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp, json
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.distributed.compression import (compressed_psum,
                                                   plain_psum_mean)
        mesh = Mesh(np.array(jax.devices()), ("d",))
        rng = np.random.default_rng(0)
        g = rng.normal(size=(8, 64)).astype(np.float32)

        def step(gl, err):
            r1 = plain_psum_mean({"w": gl}, "d")
            r2, err2 = compressed_psum({"w": gl}, "d", {"w": err})
            return r1["w"][None], r2["w"][None], err2["w"][None]

        f = jax.jit(shard_map(
            step, mesh=mesh, in_specs=(P("d", None), P("d", None)),
            out_specs=(P("d", None), P("d", None), P("d", None)),
            check_vma=False))
        err = np.zeros_like(g)
        rel_errs = []
        carry = 0.0
        for t in range(4):
            exact, comp, err = map(np.asarray, f(g, err))
            err = err.reshape(g.shape)
            rel = np.abs(comp[0] - exact[0]).max() / np.abs(exact[0]).max()
            rel_errs.append(float(rel))
        print(json.dumps({"rel_errs": rel_errs}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # int8 quantization: single-step error ~1/127; EF keeps it bounded
    assert all(r < 0.05 for r in res["rel_errs"]), res


@pytest.mark.slow
def test_production_mesh_construction():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print(dict(m1.shape), dict(m2.shape))
    """, devices=512, timeout=180)
    assert "'data': 16, 'model': 16" in out
    assert "'pod': 2" in out
