"""Engine equivalence: reference interpreter == brute force == vectorized
JAX frontier engine (incl. VCBC closed-form counting and V(G) wedge plans)."""

import numpy as np
import pytest

from repro.core.engine_jax import enumerate_graph
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan, generate_optimized_plan
from repro.core.ref_engine import (GraphDB, RefEngine,
                                   count_isomorphic_subgraphs,
                                   enumerate_matches_brute)
from repro.core.symmetry import symmetry_breaking_constraints
from repro.graph.generate import erdos_renyi, powerlaw, toy_graph_fig1

GRAPHS = {
    "toy": toy_graph_fig1(),
    "er": erdos_renyi(50, 200, seed=1),
    "pl": powerlaw(50, 4, seed=2),
}
PATTERNS = ["triangle", "square", "chordal-square", "clique4", "house",
            "q6", "fan5"]


@pytest.mark.parametrize("pname", PATTERNS)
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_ref_vs_brute_vs_jax(pname, gname):
    p = get_pattern(pname)
    g = GRAPHS[gname]
    plan = generate_best_plan(p, g.stats())
    ref = RefEngine(plan, p, g)
    ref.run()
    brute = len(enumerate_matches_brute(
        p, g, symmetry_breaking_constraints(p)))
    jres = enumerate_graph(plan, g, batch=32)
    assert ref.counters.matches == brute == jres["count"]


@pytest.mark.parametrize("pname", ["triangle", "chordal-square", "house"])
def test_jax_vcbc_counts(pname):
    p = get_pattern(pname)
    g = GRAPHS["pl"]
    brute = len(enumerate_matches_brute(
        p, g, symmetry_breaking_constraints(p)))
    plan = generate_best_plan(p, g.stats(), vcbc=True)
    try:
        res = enumerate_graph(plan, g, batch=32)
    except NotImplementedError:
        pytest.skip(">2 non-core vertices")
    assert res["count"] == brute


def test_match_sets_equal_not_just_counts():
    p = get_pattern("chordal-square")
    g = GRAPHS["er"]
    plan = generate_best_plan(p, g.stats())
    ref = RefEngine(plan, p, g, collect="matches")
    ref.run()
    res = enumerate_graph(plan, g, batch=16, collect_matches=True)
    got = {tuple(int(x) for x in row) for row in res["matches"]}
    assert got == set(ref.matches)


def test_subgraph_count_via_automorphisms():
    p = get_pattern("triangle")
    g = GRAPHS["er"]
    cnt = count_isomorphic_subgraphs(p, g)
    plan = generate_best_plan(p, g.stats())
    res = enumerate_graph(plan, g, batch=32)
    assert res["count"] == cnt         # symmetry breaking = 1 match/subgraph


def test_overflow_retry_is_exact():
    """Tiny capacities force overflow; the driver must still be exact."""
    p = get_pattern("house")
    g = GRAPHS["pl"]
    plan = generate_best_plan(p, g.stats())
    brute = len(enumerate_matches_brute(
        p, g, symmetry_breaking_constraints(p)))
    n_enu = sum(1 for i in plan.instrs if i.op == "ENU")
    res = enumerate_graph(plan, g, batch=8, caps=[16] * n_enu,
                          max_retries=12)
    assert res["count"] == brute
    assert res["chunks_retried"] > 0   # the tiny caps actually overflowed


def test_db_cache_hit_rate_locality():
    """Paper Fig. 10: bigger cache => fewer remote queries."""
    p = get_pattern("chordal-square")
    g = GRAPHS["pl"]
    plan = generate_best_plan(p, g.stats())
    remote = []
    for cap in (0, 8, g.n):
        db = GraphDB(g, cache_capacity=cap)
        eng = RefEngine(plan, p, g, db=db)
        eng.run()
        remote.append(db.remote_queries)
    assert remote[0] >= remote[1] >= remote[2]
    assert remote[2] <= g.n            # full cache: each row fetched once


def test_task_splitting_bounds_work():
    """Paper Fig. 11: theta splitting caps per-task work spread."""
    p = get_pattern("triangle")
    g = powerlaw(80, 6, seed=3)
    plan = generate_best_plan(p, g.stats())
    eng_a = RefEngine(plan, p, g)
    eng_a.run()
    eng_b = RefEngine(plan, p, g)
    eng_b.run(theta=8)
    assert eng_a.counters.matches == eng_b.counters.matches
    assert max(eng_b.counters.per_task_work) <= \
        max(eng_a.counters.per_task_work)
    assert len(eng_b.counters.per_task_work) > \
        len(eng_a.counters.per_task_work)
