"""The GPU fetch path: fused gather+intersect kernel + dispatch registry.

Three layers under test:

* kernels/gather_intersect.py — the fused Pallas kernel must be bit-equal
  to gather-then-``intersect_padded`` (interpret mode on this CPU
  container), including duplicate/sentinel ids and all-sentinel rows —
  the hypothesis property test sweeps exactly those corners;
* kernels/dispatch.py — the one impl-resolution order (explicit > env >
  platform x width registry), the tile table clamps, and the shared
  operand padding;
* the ``jax-gpu`` Executor backend — the fused path behind the unified
  driver stays exact (the full pattern-matrix conformance rows live in
  tests/test_conformance.py).
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, ops, ref


def _rand_padded_sets(rng, b, d, n):
    rows = np.full((b, d), n, np.int32)
    for i in range(b):
        k = int(rng.integers(0, min(d, n) + 1))
        rows[i, :k] = np.sort(rng.choice(n, size=k, replace=False))
    return rows


def _rand_adjacency(rng, n, d):
    adj = np.full((n + 1, d), n, np.int32)   # row n = all-sentinel
    for v in range(n):
        k = int(rng.integers(0, min(d, n) + 1))
        adj[v, :k] = np.sort(rng.choice(n, size=k, replace=False))
    return adj


class TestFusedGatherIntersect:
    @pytest.mark.parametrize("b,dc,d", [(1, 128, 128), (8, 128, 128),
                                        (16, 256, 128), (5, 64, 256),
                                        (32, 128, 384)])
    def test_sweep_vs_gather_then_intersect(self, b, dc, d):
        rng = np.random.default_rng(b * 1000 + dc + d)
        n = 2 * d
        adj = _rand_adjacency(rng, n, d)
        cand = _rand_padded_sets(rng, b, dc, n)
        ids = rng.integers(0, n + 1, size=b).astype(np.int32)
        want = ops.intersect_padded(jnp.asarray(cand),
                                    jnp.asarray(adj[np.clip(ids, 0, n)]),
                                    n, impl="ref")
        got = ops.fused_gather_intersect(jnp.asarray(cand),
                                         jnp.asarray(ids),
                                         jnp.asarray(adj), n,
                                         impl="interpret")
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_out_of_range_ids_clip_to_sentinel_row(self):
        n, d = 40, 128
        rng = np.random.default_rng(7)
        adj = _rand_adjacency(rng, n, d)
        cand = _rand_padded_sets(rng, 8, d, n)
        ids = np.array([-3, 0, n, n + 99, 1, 2, n, -1], np.int32)
        got = ops.fused_gather_intersect(jnp.asarray(cand),
                                         jnp.asarray(ids),
                                         jnp.asarray(adj), n,
                                         impl="interpret")
        want = ops.intersect_padded(jnp.asarray(cand),
                                    jnp.asarray(adj[np.clip(ids, 0, n)]),
                                    n, impl="ref")
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_fallback_impls_match(self):
        """ref/chunked/binary fall back to gather-then-intersect."""
        n, d = 60, 128
        rng = np.random.default_rng(3)
        adj = _rand_adjacency(rng, n, d)
        cand = _rand_padded_sets(rng, 8, d, n)
        ids = rng.integers(0, n + 1, size=8).astype(np.int32)
        outs = [np.asarray(ops.fused_gather_intersect(
            jnp.asarray(cand), jnp.asarray(ids), jnp.asarray(adj), n,
            impl=impl)) for impl in ("ref", "chunked", "binary",
                                     "interpret")]
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0], other)


# the ISSUE's property bar: fused == gather-then-intersect_padded for
# random padded rows including all-sentinel and duplicate-index batches
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 12),
           st.booleans(), st.booleans())
    def test_property_fused_matches_unfused(seed, b, all_sentinel_rows,
                                            duplicate_ids):
        rng = np.random.default_rng(seed)
        n, d, dc = 30, 128, 64
        adj = _rand_adjacency(rng, n, d)
        cand = _rand_padded_sets(rng, b, dc, n)
        if all_sentinel_rows:           # empty candidate sets stay empty
            cand[rng.integers(0, b)] = n
        ids = rng.integers(0, n + 1, size=b).astype(np.int32)
        if duplicate_ids and b > 1:     # same row served to many lanes
            ids[:] = ids[0]
        want = np.asarray(ops.intersect_padded(
            jnp.asarray(cand), jnp.asarray(adj[np.clip(ids, 0, n)]), n,
            impl="ref"))
        got = np.asarray(ops.fused_gather_intersect(
            jnp.asarray(cand), jnp.asarray(ids), jnp.asarray(adj), n,
            impl="interpret"))
        np.testing.assert_array_equal(want, got)
except ImportError:                      # pragma: no cover
    pytestmark_hyp = pytest.mark.skip(
        "property tests need the hypothesis dev dep")

    @pytestmark_hyp
    def test_property_fused_matches_unfused():
        pass


class TestDispatch:
    def test_explicit_impl_always_wins(self, monkeypatch):
        monkeypatch.setenv("REPRO_INTERSECT_IMPL", "pallas-interpret")
        assert dispatch.resolve_impl("intersect", "binary") == "binary"
        assert dispatch.resolve_impl("intersect", "ref") == "ref"
        # aliases normalize wherever they appear
        assert dispatch.resolve_impl("intersect",
                                     "pallas-interpret") == "interpret"

    def test_env_overrides_auto_for_every_op(self, monkeypatch):
        for op, env in (("intersect", "REPRO_INTERSECT_IMPL"),
                        ("gather_intersect",
                         "REPRO_GATHER_INTERSECT_IMPL"),
                        ("flash_attention", "REPRO_FLASH_ATTENTION_IMPL"),
                        ("rmsnorm", "REPRO_RMSNORM_IMPL")):
            monkeypatch.setenv(env, "pallas-interpret")
            assert dispatch.resolve_impl(op) == "interpret", op
            monkeypatch.delenv(env)

    def test_platform_width_defaults(self, monkeypatch):
        monkeypatch.delenv("REPRO_INTERSECT_IMPL", raising=False)
        assert dispatch.resolve_impl("intersect", platform="tpu") == "pallas"
        assert dispatch.resolve_impl("intersect", platform="cpu",
                                     width=64) == "ref"
        assert dispatch.resolve_impl("intersect", platform="cpu",
                                     width=1024) == "chunked"
        monkeypatch.delenv("REPRO_GATHER_INTERSECT_IMPL", raising=False)
        assert dispatch.resolve_impl("gather_intersect",
                                     platform="gpu") == "pallas"
        assert dispatch.resolve_impl("gather_intersect",
                                     platform="cpu") == "ref"

    def test_unknown_op_and_impl_raise(self):
        with pytest.raises(ValueError, match="unknown kernel op"):
            dispatch.resolve_impl("nope")
        with pytest.raises(ValueError, match="unknown impl"):
            dispatch.resolve_impl("intersect", "cuda")

    def test_tile_table_clamps(self):
        # table hit
        assert dispatch.pick_tiles("intersect", 64, 256,
                                   platform="cpu") == (8, 128)
        # bk must divide width; bm stays at the table value — the ops.py
        # wrappers pad the batch up to a bm multiple after picking tiles
        assert dispatch.pick_tiles("intersect", 7, 200,
                                   platform="cpu") == (8, 200)
        # per-call override, still bk-clamped
        assert dispatch.pick_tiles("intersect", 64, 256, platform="cpu",
                                   bm=4, bk=64) == (4, 64)
        assert dispatch.pick_tiles("intersect", 64, 200, platform="cpu",
                                   bk=64) == (8, 200)

    def test_pad_operands_mixed_width(self):
        a = jnp.asarray(np.arange(6, dtype=np.int32).reshape(3, 2))
        b = jnp.asarray(np.arange(12, dtype=np.int32).reshape(3, 4))
        ap, bp = dispatch.pad_operands(a, b, sentinel=99, bm=2)
        assert ap.shape == (4, 4) and bp.shape == (4, 4)
        assert int(ap[0, 3]) == 99 and int(ap[3, 0]) == 99
        np.testing.assert_array_equal(np.asarray(bp[:3]), np.asarray(b))

    def test_fused_fetch_toggle(self, monkeypatch):
        monkeypatch.delenv("REPRO_FUSED_FETCH", raising=False)
        assert dispatch.fused_fetch_enabled() is False
        assert dispatch.fused_fetch_enabled(True) is True
        monkeypatch.setenv("REPRO_FUSED_FETCH", "1")
        assert dispatch.fused_fetch_enabled() is True
        monkeypatch.setenv("REPRO_FUSED_FETCH", "off")
        assert dispatch.fused_fetch_enabled(True) is False


class TestBinaryImplValidation:
    """The ISSUE bugfix: impl='binary' violations raise a clear
    ValueError instead of an opaque vmap/searchsorted shape error (or
    silently wrong memberships)."""

    def test_unsorted_b_raises(self):
        a = jnp.asarray([[1, 2, 9, 9]], jnp.int32)
        b = jnp.asarray([[3, 1, 2, 9]], jnp.int32)     # out of order
        with pytest.raises(ValueError, match="fully ascending"):
            ops.intersect_padded(a, b, 9, impl="binary")

    def test_interspersed_holes_raise(self):
        a = jnp.asarray([[1, 2, 9, 9]], jnp.int32)
        b = jnp.asarray([[1, 9, 2, 9]], jnp.int32)     # hole mid-row
        with pytest.raises(ValueError, match="fully ascending"):
            ops.intersect_padded(a, b, 9, impl="binary")

    def test_shape_violations_raise(self):
        a = jnp.asarray([1, 2, 9], jnp.int32)          # 1-D
        b = jnp.asarray([[1, 2, 9]], jnp.int32)
        with pytest.raises(ValueError, match="2-D operands"):
            ops.intersect_padded(a, b, 9, impl="binary")
        with pytest.raises(ValueError, match="shared batch"):
            ops.intersect_padded(jnp.zeros((2, 4), jnp.int32),
                                 jnp.zeros((3, 4), jnp.int32), 9,
                                 impl="binary")

    def test_valid_operands_still_work_and_jit(self):
        a = jnp.asarray([[0, 2, 5, 9]], jnp.int32)
        b = jnp.asarray([[2, 3, 5, 9]], jnp.int32)
        want = np.asarray(ref.sorted_intersect(a, b, 9))
        got = np.asarray(ops.intersect_padded(a, b, 9, impl="binary"))
        np.testing.assert_array_equal(want, got)
        # under jit the operands are tracers: the invariant is trusted,
        # the check must not trip on them
        jitted = jax.jit(lambda x, y: ops.intersect_padded(
            x, y, 9, impl="binary"))
        np.testing.assert_array_equal(np.asarray(jitted(a, b)), want)


class TestFusedEngineWiring:
    def test_classification_single_use_non_first_only(self):
        from repro.core.engine_jax import classify_fusable_dbqs
        from repro.core.pattern import get_pattern
        from repro.core.plangen import generate_best_plan
        from repro.graph.generate import erdos_renyi
        g = erdos_renyi(64, 256, seed=11)
        plan = generate_best_plan(get_pattern("square"), g.stats())
        fusable = classify_fusable_dbqs(plan)
        dbqs = [i.target for i in plan.instrs if i.op == "DBQ"]
        # square: T5 := Intersect(A1, A3) — A3 (non-first, single-use)
        # fuses, A1 (first operand) stays materialized
        assert dbqs[1] in fusable and dbqs[0] not in fusable

    def test_jax_gpu_backend_quick_conformance(self):
        from repro.core.executor import make_executor
        from repro.core.pattern import get_pattern
        from repro.core.plangen import generate_best_plan
        from repro.graph.generate import powerlaw
        g = powerlaw(48, 4, seed=9)
        plan = generate_best_plan(get_pattern("triangle"), g.stats())
        ref_st = make_executor("ref").run(plan, g, batch=16)
        gpu_st = make_executor(
            "jax-gpu", gather_intersect_impl="interpret").run(
                plan, g, batch=16)
        assert gpu_st.count == ref_st.count
        assert gpu_st.extras["fused_fetch"] is True

    def test_env_can_turn_jax_gpu_fusion_off(self, monkeypatch):
        """REPRO_FUSED_FETCH=0 must be honoured by jax-gpu too (the A/B
        debugging path), not silently ignored."""
        from repro.core.executor import ExecutorConfig, JaxGpuBackend, drive
        from repro.core.pattern import get_pattern
        from repro.core.plangen import generate_best_plan
        from repro.graph.generate import powerlaw
        monkeypatch.setenv("REPRO_FUSED_FETCH", "0")
        g = powerlaw(48, 4, seed=9)
        plan = generate_best_plan(get_pattern("triangle"), g.stats())
        be = JaxGpuBackend()
        st = drive(be, plan, g, ExecutorConfig(batch=16))
        assert be.fused is False
        assert st.extras["fused_fetch"] is False
        from repro.core.executor import make_executor
        assert st.count == make_executor("ref").run(plan, g, batch=16).count

    def test_env_forces_fused_on_plain_jax_backend(self, monkeypatch):
        from repro.core.executor import ExecutorConfig, JaxBackend, drive
        from repro.core.pattern import get_pattern
        from repro.core.plangen import generate_best_plan
        from repro.graph.generate import powerlaw
        monkeypatch.setenv("REPRO_FUSED_FETCH", "1")
        monkeypatch.setenv("REPRO_GATHER_INTERSECT_IMPL",
                           "pallas-interpret")
        g = powerlaw(48, 4, seed=9)
        plan = generate_best_plan(get_pattern("triangle"), g.stats())
        be = JaxBackend()
        st = drive(be, plan, g, ExecutorConfig(batch=16))
        assert be.fused is True
        from repro.core.executor import make_executor
        assert st.count == make_executor("ref").run(plan, g, batch=16).count
