"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in kernels/ref.py (+ hypothesis property tests on the
padded-set algebra)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis dev dep")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rand_padded_sets(rng, b, d, n):
    rows = np.full((b, d), n, np.int32)
    for i in range(b):
        k = int(rng.integers(0, min(d, n) + 1))
        rows[i, :k] = np.sort(rng.choice(n, size=k, replace=False))
    return rows


class TestSortedIntersect:
    @pytest.mark.parametrize("b,d", [(1, 128), (8, 128), (16, 256),
                                     (5, 384), (32, 512)])
    def test_sweep_vs_ref(self, b, d):
        rng = np.random.default_rng(b * 1000 + d)
        n = 3 * d
        a = jnp.asarray(_rand_padded_sets(rng, b, d, n))
        bb = jnp.asarray(_rand_padded_sets(rng, b, d, n))
        want = ref.sorted_intersect(a, bb, n)
        got = ops.intersect_padded(a, bb, n, impl="interpret")
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    @pytest.mark.parametrize("chunk", [32, 128, 200])
    def test_chunked_vs_ref(self, chunk):
        rng = np.random.default_rng(chunk)
        n = 500
        a = jnp.asarray(_rand_padded_sets(rng, 12, 256, n))
        b = jnp.asarray(_rand_padded_sets(rng, 12, 256, n))
        want = ref.sorted_intersect(a, b, n)
        got = ref.sorted_intersect_chunked(a, b, n, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.sets(st.integers(0, 49), max_size=16), min_size=1,
                    max_size=6),
           st.lists(st.sets(st.integers(0, 49), max_size=16), min_size=1,
                    max_size=6))
    def test_property_matches_python_sets(self, sa, sb):
        """Padded intersection == python set intersection, row-wise."""
        rows = max(len(sa), len(sb))
        sa = (sa * rows)[:rows]
        sb = (sb * rows)[:rows]
        n, d = 50, 32
        a = np.full((rows, d), n, np.int32)
        b = np.full((rows, d), n, np.int32)
        for i in range(rows):
            va = sorted(sa[i])[:d]
            vb = sorted(sb[i])[:d]
            a[i, :len(va)] = va
            b[i, :len(vb)] = vb
        out = np.asarray(ref.sorted_intersect(jnp.asarray(a),
                                              jnp.asarray(b), n))
        for i in range(rows):
            got = {int(x) for x in out[i] if x != n}
            assert got == (sa[i] & sb[i])
            # order/positions of surviving entries preserved
            kept = out[i][out[i] != n]
            assert list(kept) == sorted(kept)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 128), (3, 7, 256), (1, 512),
                                       (16, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep_vs_ref(self, shape, dtype):
        rng = np.random.default_rng(hash((shape, str(dtype))) % 2**31)
        x = jnp.asarray(rng.normal(size=shape), dtype)
        g = jnp.asarray(rng.normal(size=shape[-1:]), dtype)
        want = ref.rmsnorm(x, g)
        got = ops.rmsnorm(x, g, impl="interpret")
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(want, np.float32),
                                   np.asarray(got, np.float32),
                                   rtol=tol, atol=tol)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,tq,tk,d", [
        (1, 2, 2, 128, 128, 64),
        (2, 4, 2, 128, 256, 64),      # GQA group 2, decode-offset masking
        (1, 8, 1, 256, 256, 128),     # MQA
        (2, 2, 2, 128, 128, 128),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep_vs_ref(self, b, hq, hkv, tq, tk, d, causal):
        rng = np.random.default_rng(b + hq + tq + tk + causal)
        q = jnp.asarray(rng.normal(size=(b, hq, tq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, hkv, tk, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, hkv, tk, d)), jnp.float32)
        want = ref.flash_attention(q, k, v, causal=causal)
        got = ops.flash_attention(q, k, v, causal=causal, impl="interpret")
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
        want = ref.flash_attention(q, k, v)
        got = ops.flash_attention(q, k, v, impl="interpret")
        np.testing.assert_allclose(np.asarray(want, np.float32),
                                   np.asarray(got, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestBlockwiseAttention:
    """The jnp flash formulation used by the models on CPU/dry-run."""

    @pytest.mark.parametrize("tq,tk,block", [(64, 64, 16), (64, 128, 32),
                                             (1, 96, 32), (128, 128, 128)])
    def test_vs_ref(self, tq, tk, block):
        from repro.layers.attention import blockwise_attention
        rng = np.random.default_rng(tq + tk + block)
        b, h, d = 2, 3, 32
        q = jnp.asarray(rng.normal(size=(b, tq, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, tk, h, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, tk, h, d)), jnp.float32)
        got = blockwise_attention(q, k, v, causal=True, block=block)
        want = ref.flash_attention(jnp.moveaxis(q, 2, 1),
                                   jnp.moveaxis(k, 2, 1),
                                   jnp.moveaxis(v, 2, 1), causal=True)
        np.testing.assert_allclose(np.asarray(jnp.moveaxis(got, 2, 1)),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)
