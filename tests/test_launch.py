"""Launcher-layer tests: the HLO roofline analyzer on a crafted module,
the enumerate CLI end-to-end, and registry/input-spec sanity."""

import os
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import analyze, parse_computations

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MINI_HLO = """
HloModule mini, is_scheduled=true

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} parameter(1)
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[4,2]<=[8], channel_id=1
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8,16], w: f32[16,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %w = f32[16,16]{1,0} parameter(1)
  %init = (s32[], f32[8,16]) tuple(%a, %a)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[64,16]{1,0} all-gather(%a), replica_groups=[1,8]<=[8], channel_id=2, dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


class TestHloAnalysis:
    def test_computations_parsed(self):
        comps = parse_computations(MINI_HLO)
        assert {"cond.1", "body.1", "main"} <= set(comps)
        assert comps["main"].is_entry

    def test_trip_count_multiplication(self):
        tot = analyze(MINI_HLO)
        # dot: 2 * 8 * 16 * 16 = 4096 flops, x5 loop trips
        assert tot.flops == pytest.approx(5 * 4096)

    def test_collective_accounting(self):
        tot = analyze(MINI_HLO)
        # all-reduce f32[8,16] (512B) x5 trips + one all-gather
        assert tot.coll_operand_bytes["all-reduce"] == pytest.approx(
            5 * 8 * 16 * 4)
        # all-gather result 64x16 f32, group 8 -> operand = result/8
        assert tot.coll_operand_bytes["all-gather"] == pytest.approx(
            64 * 16 * 4 / 8)
        assert tot.coll_count == 6

    def test_wire_model(self):
        tot = analyze(MINI_HLO)
        # ring all-reduce: 2 * bytes * (g-1)/g, g=2
        assert tot.coll_wire_bytes["all-reduce"] == pytest.approx(
            5 * 2 * 512 * 0.5)


@pytest.mark.slow
def test_enumerate_cli_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.enumerate",
         "--pattern", "triangle", "--n", "200", "--edges", "800",
         "--devices", "4", "--hot", "16", "--rebalance",
         "--batch-per-shard", "32"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "matches" in out.stdout
    # cross-check the reported count against brute force
    import re

    from repro.core.pattern import get_pattern
    from repro.core.ref_engine import enumerate_matches_brute
    from repro.core.symmetry import symmetry_breaking_constraints
    from repro.graph.generate import powerlaw
    m = re.search(r"matches\s*:\s*(\d+)", out.stdout)
    g = powerlaw(200, max(800 // 200, 2), seed=0)
    want = len(enumerate_matches_brute(
        get_pattern("triangle"), g,
        symmetry_breaking_constraints(get_pattern("triangle"))))
    assert int(m.group(1)) == want


@pytest.mark.slow
def test_dryrun_single_cell_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gin-tu",
         "--shape", "molecule", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
    import glob
    import json
    files = glob.glob("/tmp/dryrun_test/*.json")
    assert files
    r = json.load(open(files[0]))
    assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert r["memory_analysis"]["peak_bytes_per_device"] > 0
