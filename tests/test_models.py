"""Per-architecture smoke tests: every assigned arch instantiates its
reduced config and runs one forward/train step on CPU — output shapes
correct and no NaNs (plus decode-path and serving smokes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs

LM_ARCHS = ["phi4-mini-3.8b", "qwen2-0.5b", "qwen2.5-3b",
            "deepseek-v2-lite-16b", "granite-moe-3b-a800m"]
GNN_ARCHS = ["meshgraphnet", "pna", "egnn", "gin-tu"]


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import init_params, loss_fn
    from repro.train.optimizer import (AdamWConfig, adamw_init,
                                       adamw_update)
    spec = get_config(arch).smoke()
    cfg = spec.model_cfg
    params = init_params(jax.random.PRNGKey(0), cfg)
    dims = spec.shapes["train"].dims
    b, t = dims["batch"], dims["seq"]
    batch = {"tokens": jnp.zeros((b, t), jnp.int32),
             "labels": jnp.ones((b, t), jnp.int32)}
    (loss, mets), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert jnp.isfinite(loss)
    opt = adamw_init(params)
    new_p, new_o, om = adamw_update(AdamWConfig(), grads, opt, params)
    assert _finite(new_p)
    assert jnp.isfinite(om["grad_norm"])


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    from repro.models.transformer import (decode_step, init_caches,
                                          init_params)
    spec = get_config(arch).smoke()
    cfg = spec.model_cfg
    params = init_params(jax.random.PRNGKey(1), cfg)
    dims = spec.shapes["decode"].dims
    b, s = dims["batch"], dims["seq"]
    caches = init_caches(cfg, b, s)
    logits, caches = decode_step(params, caches,
                                 jnp.zeros((b, 1), jnp.int32),
                                 jnp.zeros((), jnp.int32), cfg)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # second step advances lengths
    logits2, caches = decode_step(params, caches,
                                  jnp.ones((b, 1), jnp.int32),
                                  jnp.ones((), jnp.int32), cfg)
    for stack in caches.values():
        assert int(stack["length"][0]) == 2


def test_decode_matches_full_forward():
    """Token-by-token decode logits == full causal forward logits."""
    from repro.models.transformer import (decode_step, forward, init_caches,
                                          init_params)
    spec = get_config("qwen2-0.5b").smoke()
    cfg = spec.model_cfg
    params = init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(0)
    b, t = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32)
    full_logits, _, _ = forward(params, toks, cfg)
    caches = init_caches(cfg, b, t + 1)
    outs = []
    for i in range(t):
        lg, caches = decode_step(params, caches, toks[:, i:i + 1],
                                 jnp.asarray(i, jnp.int32), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    from repro.graph.batch import synthetic_full_graph, synthetic_mesh
    from repro.models.gnn import gnn_loss, init_gnn_params
    spec = get_config(arch).smoke()
    cfg = spec.model_cfg_for("full")
    dims = spec.shapes["full"].dims
    if cfg.task == "node_reg":
        gb = synthetic_mesh(dims["n_nodes"], dims["n_edges"], cfg.d_feat,
                            cfg.d_edge)
    else:
        gb = synthetic_full_graph(dims["n_nodes"], dims["n_edges"] // 2,
                                  cfg.d_feat, cfg.n_out)
    batch = gb.as_arrays()
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    (loss, mets), grads = jax.value_and_grad(
        lambda p: gnn_loss(p, batch, cfg), has_aux=True)(params)
    assert jnp.isfinite(loss)
    assert _finite(grads)


def test_gnn_molecule_graph_classification():
    from repro.graph.batch import synthetic_molecules
    from repro.models.gnn import gnn_forward, init_gnn_params
    spec = get_config("gin-tu").smoke()
    cfg = spec.model_cfg_for("mol")
    gb = synthetic_molecules(8, 10, 20, cfg.d_feat, cfg.n_out)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    out = gnn_forward(params, gb.as_arrays(), cfg)
    assert out.shape == (8, cfg.n_out)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_neighbor_sampler_block():
    from repro.graph.batch import NeighborSampler
    from repro.graph.generate import powerlaw
    g = powerlaw(500, 5, seed=0)
    s = NeighborSampler(g, fanouts=[5, 3], seed=1)
    n_max, e_max = s.capacity(16)
    feats = np.random.default_rng(0).normal(
        size=(g.n, 12)).astype(np.float32)
    labels = np.zeros(g.n, np.int32)
    batch = s.sample_batch(np.arange(16), feats, labels, n_max, e_max)
    assert batch.x.shape == (n_max, 12)
    valid_edges = batch.edge_src < n_max
    assert valid_edges.sum() > 0
    # every sampled edge stays inside the block
    assert (batch.edge_dst[valid_edges] < n_max).all()
    assert batch.loss_mask[:16].all()


def test_bst_smoke_and_retrieval_consistency():
    from repro.models.bst import (bst_loss, bst_retrieval, bst_scores,
                                  init_bst_params)
    spec = get_config("bst").smoke()
    cfg = spec.model_cfg
    params = init_bst_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    b = spec.shapes["train"].dims["batch"]
    batch = {
        "hist": jnp.asarray(rng.integers(1, cfg.n_items, (b, cfg.seq_len)),
                            jnp.int32),
        "target": jnp.asarray(rng.integers(1, cfg.n_items, (b,)),
                              jnp.int32),
        "user_feats": jnp.asarray(
            rng.integers(0, cfg.n_user_feats, (b, cfg.user_feat_len)),
            jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, (b,)), jnp.float32),
    }
    loss, mets = bst_loss(params, batch, cfg)
    assert jnp.isfinite(loss)
    cands = jnp.arange(64, dtype=jnp.int32)
    r = bst_retrieval(params, batch["hist"][:1], batch["user_feats"][:1],
                      cands, cfg)
    direct = bst_scores(
        params, jnp.broadcast_to(batch["hist"][:1], (64, cfg.seq_len)),
        cands,
        jnp.broadcast_to(batch["user_feats"][:1], (64, cfg.user_feat_len)),
        cfg)
    np.testing.assert_allclose(np.asarray(r), np.asarray(direct),
                               rtol=1e-4, atol=1e-4)


def test_embedding_bag_modes():
    from repro.layers.embedding_bag import embedding_bag, embedding_bag_fixed
    table = jnp.asarray(np.arange(40, dtype=np.float32).reshape(10, 4))
    ids = jnp.asarray([1, 2, 2, 0, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    out = embedding_bag(table, ids, seg, num_segments=2, mode="sum")
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(table[1] + table[2]))
    out_m = embedding_bag(table, ids, seg, num_segments=2, mode="mean")
    np.testing.assert_allclose(np.asarray(out_m[1]),
                               np.asarray((table[2] + table[0] + table[5])
                                          / 3))
    fixed = embedding_bag_fixed(table, jnp.asarray([[1, 2, 0]], jnp.int32),
                                mode="mean", pad_id=0)
    np.testing.assert_allclose(np.asarray(fixed[0]),
                               np.asarray((table[1] + table[2]) / 2))


def test_all_archs_registered_with_smoke():
    for arch in list_archs():
        spec = get_config(arch)
        smoke = spec.smoke()
        assert smoke.family == spec.family
        for shape in spec.shapes:
            specs = spec.input_specs(shape)
            assert specs, (arch, shape)
