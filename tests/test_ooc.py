"""Out-of-core fetch path: HostRowStore, DeviceRowCache, oocache engine,
and the host-mode streaming snapshot store.

The correctness bar mirrors the other engines: exact agreement with the
reference interpreter / brute force at *any* cache capacity — capacity
only changes how many rows cross from the host, which the counters must
report faithfully (they are the Fig. 10 measurement).
"""

import numpy as np
import pytest

from repro.core.executor import make_executor, plan_enu_count
from repro.core.pattern import get_pattern
from repro.core.plangen import generate_best_plan
from repro.distributed.rowcache import DeviceRowCache
from repro.graph.generate import erdos_renyi, powerlaw
from repro.graph.hoststore import HostRowStore
from repro.graph.storage import DiGraph

GRAPHS = {
    "er": erdos_renyi(64, 256, seed=11),
    "pl": powerlaw(64, 4, seed=12),
}


# --------------------------------------------------------------------------
# HostRowStore: sharded host build == the dense padded_adjacency oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rps", [4, 17, 65, 4096])
def test_host_store_matches_padded_adjacency(rps):
    g = GRAPHS["pl"]
    store = HostRowStore.from_graph(g, rows_per_shard=rps)
    rows, _ = g.padded_adjacency(lane=8)
    oracle = np.concatenate(
        [rows, np.full((1, rows.shape[1]), g.n, np.int32)], axis=0)
    assert store.n_rows == g.n + 1
    assert store.d == rows.shape[1]
    np.testing.assert_array_equal(store.to_rows(), oracle)
    # random id batches, including sentinel and out-of-range ids
    rng = np.random.default_rng(0)
    ids = rng.integers(-2, g.n + 3, size=50)
    got = store.gather(ids)
    np.testing.assert_array_equal(got, oracle[np.clip(ids, 0, g.n)])


def test_host_store_shard_count_and_set_rows():
    g = GRAPHS["er"]
    store = HostRowStore.from_graph(g, rows_per_shard=10)
    assert len(store.shards) == -(-(g.n + 1) // 10)
    assert store.nbytes == sum(s.nbytes for s in store.shards)
    row = np.full(store.d, g.n, np.int32)
    row[:2] = [1, 5]
    store.set_rows(np.array([3]), row[None])
    np.testing.assert_array_equal(store.row(3), row)
    with pytest.raises(ValueError):
        store.set_rows(np.array([g.n]), row[None])   # sentinel immutable


def test_host_store_from_digraph_both_directions():
    g = DiGraph.from_edges(6, [(0, 1), (0, 2), (3, 0), (4, 5)])
    out = HostRowStore.from_digraph(g, "out", rows_per_shard=3)
    inn = HostRowStore.from_digraph(g, "in", rows_per_shard=3)
    assert sorted(int(x) for x in out.row(0) if x != 6) == [1, 2]
    assert sorted(int(x) for x in inn.row(0) if x != 6) == [3]
    assert list(out.row(6)) == [6] * out.d          # sentinel row


# --------------------------------------------------------------------------
# DeviceRowCache: exact at any capacity; counters honest
# --------------------------------------------------------------------------


@pytest.mark.parametrize("cap,hot", [(0, 0), (5, 0), (0, 8), (5, 8),
                                     (64, 64), (1000, 0)])
def test_cache_serves_exact_rows_any_capacity(cap, hot):
    g = GRAPHS["pl"]
    store = HostRowStore.from_graph(g, rows_per_shard=16)
    cache = DeviceRowCache(store, cap, hot=hot)
    oracle = store.to_rows()
    rng = np.random.default_rng(1)
    for lvl in range(4):
        ids = rng.integers(0, g.n + 1, size=40)
        got = np.asarray(cache.lookup(ids, level=lvl))
        np.testing.assert_array_equal(got, oracle[ids])
    st = cache.stats
    assert st.lookups == 4
    assert st.queries <= 160                # sentinel ids are not queries
    assert cache.device_rows == \
        cap + 2 * (cap // 4) + min(hot, g.n) + 1


def test_cache_counters_and_lru_reuse():
    g = GRAPHS["er"]
    store = HostRowStore.from_graph(g)
    cache = DeviceRowCache(store, capacity_rows=16, hot=0)
    ids = np.arange(8)
    cache.lookup(ids)
    st = cache.stats
    assert st.queries == 8 and st.cold_rows == 8
    assert st.bytes_demand == 8 * store.d * 4
    cache.lookup(ids)                      # second pass: all slab hits
    assert st.cold_rows == 8 and st.queries == 16
    assert st.hit_rate == pytest.approx(0.5)
    # within-batch dedup: 8 copies of one id cost at most one cold row
    cache.lookup(np.full(8, 60))
    assert st.cold_rows == 9


def test_cache_hot_rows_pinned_never_cold():
    g = GRAPHS["pl"]
    store = HostRowStore.from_graph(g)
    cache = DeviceRowCache(store, capacity_rows=0, hot=8)
    hot_ids = np.arange(g.n - 8, g.n)      # ascending-degree relabel: top 8
    got = np.asarray(cache.lookup(hot_ids))
    np.testing.assert_array_equal(got, store.to_rows()[hot_ids])
    assert cache.stats.cold_rows == 0
    assert cache.stats.hot_hits == 8


def test_cache_prefetch_stages_then_serves_without_demand_fetch():
    g = GRAPHS["er"]
    store = HostRowStore.from_graph(g)
    cache = DeviceRowCache(store, capacity_rows=32, hot=0, stage_rows=16)
    cache.prefetch(np.arange(10))
    assert cache.stats.prefetch_rows == 10
    assert cache.stats.bytes_prefetch == 10 * store.d * 4
    got = np.asarray(cache.lookup(np.arange(10)))
    np.testing.assert_array_equal(got, store.to_rows()[:10])
    assert cache.stats.cold_rows == 0       # served from the staged block
    assert cache.stats.prefetch_used == 10
    # double buffering: a third staged block forces adoption of the oldest
    cache.prefetch(np.arange(10, 14))
    cache.prefetch(np.arange(14, 18))
    cache.prefetch(np.arange(18, 22))
    assert len(cache._staged) == 2


def test_cache_invalidate_after_in_place_store_update():
    """A cache kept alive while the backing shards are patched in place
    (the host-mode snapshot advance) must serve the new rows after
    invalidate() — slab entries and pinned hot rows alike."""
    g = GRAPHS["er"]
    store = HostRowStore.from_graph(g, rows_per_shard=16)
    cache = DeviceRowCache(store, capacity_rows=16, hot=8)
    cold_v, hot_v = 5, g.n - 2              # slab-cached / pinned-hot ids
    cache.lookup(np.array([cold_v, hot_v]))  # warm both paths
    newrow = np.full(store.d, g.n, np.int32)
    newrow[0] = 0
    store.set_rows(np.array([cold_v, hot_v]), np.stack([newrow, newrow]))
    cache.invalidate(np.array([cold_v, hot_v]))
    rows = np.asarray(cache.lookup(np.array([cold_v, hot_v])))
    np.testing.assert_array_equal(rows[0], newrow)
    np.testing.assert_array_equal(rows[1], newrow)


# --------------------------------------------------------------------------
# oocache engine: exact vs ref under a bounded device cache (< 25% of N)
# --------------------------------------------------------------------------


def _bounded_ooc(g, **kw):
    cap = max(1, int(g.n * 0.12))
    hot = max(1, int(g.n * 0.04))
    ex = make_executor("oocache", cache_rows=cap, hot=hot, **kw)
    # the acceptance bound counts the WHOLE device footprint: slab +
    # both prefetch staging buffers + pinned hot rows + sentinel
    assert cap + 2 * (cap // 4) + hot + 1 < 0.25 * g.n
    return ex


def test_oocache_forced_overflow_rechunks_and_stays_exact():
    g = GRAPHS["pl"]
    p = get_pattern("house")
    plan = generate_best_plan(p, g.stats())
    want = make_executor("ref").run(plan, g, batch=32).count
    st = _bounded_ooc(g).run(plan, g, batch=16,
                             caps=[8] * plan_enu_count(plan),
                             max_retries=12)
    assert st.count == want
    assert st.chunks_split > 0


def test_oocache_match_set_exact_not_just_count():
    g = GRAPHS["pl"]
    p = get_pattern("clique4")
    plan = generate_best_plan(p, g.stats())
    ref = make_executor("ref").run(plan, g, batch=32, collect_matches=True)
    ooc = _bounded_ooc(g).run(plan, g, batch=32, collect_matches=True)
    got = {tuple(int(x) for x in r) for r in ooc.matches}
    want = {tuple(int(x) for x in r) for r in ref.matches}
    assert got == want and len(ooc.matches) == len(got)
    assert len(want) > 0                    # the pattern occurs


def test_oocache_zero_capacity_still_exact():
    g = GRAPHS["er"]
    p = get_pattern("triangle")
    plan = generate_best_plan(p, g.stats())
    want = make_executor("ref").run(plan, g, batch=32).count
    st = make_executor("oocache", cache_rows=0, hot=0,
                       prefetch=False).run(plan, g, batch=32)
    assert st.count == want
    c = st.extras["cache"]
    assert c["hit_rate"] < 1.0 and c["cold_rows"] > 0


def test_oocache_universe_plan_square():
    """The square's wedge order consumes V(G) (detached vertex): the OOC
    segments must thread the universe chunk like engine_jax."""
    g = GRAPHS["er"]
    p = get_pattern("square")
    plan = generate_best_plan(p, g.stats())
    want = make_executor("ref").run(plan, g, batch=32).count
    st = _bounded_ooc(g).run(plan, g, batch=32, universe_chunk=16)
    assert st.count == want


def test_oocache_reports_fetch_accounting():
    g = GRAPHS["pl"]
    p = get_pattern("house")
    plan = generate_best_plan(p, g.stats())
    st = _bounded_ooc(g).run(plan, g, batch=32)
    c = st.extras["cache"]
    assert c["queries"] > 0 and c["cold_rows"] > 0
    assert c["bytes_moved"] == c["bytes_demand"] + c["bytes_prefetch"]
    assert 0.0 < c["hit_rate"] < 1.0
    # per-level ledger covers every DBQ level and sums to the totals
    assert sum(q for q, _, _ in c["per_level"].values()) == c["queries"]
    assert sum(cold for _, cold, _ in c["per_level"].values()) \
        == c["cold_rows"]
    assert st.extras["device_resident_rows"] < 0.25 * (g.n + 1)
    assert st.extras["host_store_bytes"] > 0


def test_oocache_prefetch_overlap_used():
    g = GRAPHS["er"]
    p = get_pattern("path5")
    plan = generate_best_plan(p, g.stats())
    st = _bounded_ooc(g).run(plan, g, batch=8)
    assert st.extras["cache"]["prefetch_used"] > 0


# --------------------------------------------------------------------------
# Host-mode streaming snapshot store (HostRowStore behind S-BENU)
# --------------------------------------------------------------------------


def test_snapshot_host_storage_stream_conformance():
    """sbenu-jax over host-RAM snapshot shards == interpreter == oracle,
    with exactly one rebuild (the stream start): every later step advances
    the shards in place."""
    from repro.core.estimate import GraphStats
    from repro.core.executor import SBenuJaxBackend
    from repro.core.sbenu import (generate_best_sbenu_plans, run_timestep,
                                  snapshot_diff_oracle)
    from repro.graph.dynamic import (DeviceSnapshotStore, SnapshotStore,
                                     stream_width_floors)
    from repro.graph.generate import edge_stream

    p = get_pattern("q2'")
    g0, batches = edge_stream(n=24, m_init=110, steps=3, batch=24,
                              seed=17, delete_frac=0.4)
    store_h = SnapshotStore(g0)
    store_r = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(
        p, GraphStats(24, 110, delta_edges=24))
    d, dd = stream_width_floors(g0, batches)
    backend = SBenuJaxBackend(snapshot_storage="host", d_min=d,
                              delta_d_min=dd)
    for batch in batches:
        want_p, want_m = snapshot_diff_oracle(p, store_h, batch)
        jp, jm, _ = run_timestep(p, plans, store_h, batch,
                                 backend=backend, chunk=16)
        rp, rm, _ = run_timestep(p, plans, store_r, batch, engine="ref")
        assert jp == rp == want_p
        assert jm == rm == want_m
    mirror = [m for m in store_h._mirrors
              if isinstance(m, DeviceSnapshotStore)][0]
    assert mirror.storage == "host"
    assert mirror.rebuilds == 1


def test_snapshot_row_source_only_stream_advances_in_place():
    """A stream served ONLY through row_source() (never step_snapshot)
    must still advance the host shards in place — one rebuild for the
    whole stream — and must survive a step whose inserts outgrow the
    pinned row width (wider rebuild, not a crash)."""
    from repro.graph.dynamic import DeviceSnapshotStore, SnapshotStore
    from repro.graph.storage import DiGraph

    n = 16
    g0 = DiGraph.from_edges(n, [(0, 1), (1, 2), (2, 3)])
    store = SnapshotStore(g0)
    mirror = DeviceSnapshotStore(store, storage="host")
    # step 1: small insert, served via row_source only
    store.begin_step([("+", 0, 2)])
    view = mirror.row_source("out", "cur")
    assert sorted(int(x) for x in view.gather([0])[0] if x != n) == [1, 2]
    store.end_step()
    assert mirror.rebuilds == 1
    assert sorted(store.prev.out[0]) == [1, 2]
    # step 2: outgrow vertex 0's pinned lane-8 width (12 inserts at once)
    ins = list(range(3, 15))
    store.begin_step([("+", 0, w) for w in ins])
    view = mirror.row_source("out", "cur")
    got = sorted(int(x) for x in view.gather([0])[0] if x != n)
    assert got == [1, 2] + ins
    store.end_step()
    assert mirror.rebuilds == 2            # wider rebuild, then in place
    # step 3: back to in-place advance at the new width
    store.begin_step([("-", 0, 1)])
    view = mirror.row_source("out", "cur")
    assert sorted(int(x) for x in view.gather([0])[0] if x != n) \
        == [2] + ins
    store.end_step()
    assert mirror.rebuilds == 2
    assert sorted(store.prev.out[0]) == [2] + ins


def test_snapshot_row_source_bounded_serving_matches_get_adj():
    """row_source('cur'/'prev') through a small DeviceRowCache must agree
    with the SnapshotStore get_adj oracle mid-step — the bounded-device
    fetch path for snapshots whose resident blocks would not fit HBM."""
    from repro.graph.dynamic import DeviceSnapshotStore, SnapshotStore
    from repro.graph.generate import edge_stream

    g0, batches = edge_stream(n=20, m_init=80, steps=1, batch=16,
                              seed=9, delete_frac=0.5)
    store = SnapshotStore(g0)
    mirror = DeviceSnapshotStore(store, storage="host")
    store.begin_step(batches[0])
    for direction in ("out", "in"):
        for which, op in (("prev", "-"), ("cur", "+")):
            view = mirror.row_source(direction, which)
            cache = DeviceRowCache(view, capacity_rows=3, hot=2)
            rows = np.asarray(cache.lookup(np.arange(store.n + 1)))
            for v in range(store.n):
                want = sorted(store.get_adj(v, "either", direction, op))
                got = sorted(int(x) for x in rows[v] if x != store.n)
                assert got == want, (direction, which, v)
            assert cache.device_rows <= 6   # 3 slab + 2 hot + sentinel
    store.end_step()
