"""Execution-plan compiler tests (paper §4): raw plans, the three
optimizations, VCBC, and the best-plan search — including a reproduction of
the paper's running example (Fig. 2)."""

import pytest

from repro.core.estimate import GraphStats
from repro.core.instructions import DBQ, ENU, INI, INT, RES, TRC, VG
from repro.core.pattern import FAN5, UNDIRECTED_PATTERNS, get_pattern
from repro.core.plangen import (apply_triangle_cache,
                                common_subexpression_elimination,
                                estimate_communication_cost,
                                estimate_computation_cost,
                                generate_best_plan, generate_optimized_plan,
                                generate_raw_plan, reorder_instructions,
                                search_matching_orders)
from repro.core.symmetry import (check_unique_representative,
                                 symmetry_breaking_constraints)

# the paper's running example: fan5 with O: u1,u3,u5,u2,u6,u4 (0-based)
FIG2_ORDER = (0, 2, 4, 1, 5, 3)


def _well_formed(plan):
    """All variables defined before use; one INI; RES last."""
    defined = {VG}
    assert plan.instrs[0].op == INI
    assert plan.instrs[-1].op == RES
    for ins in plan.instrs:
        for v in ins.uses():
            if v[0] == "op":
                continue
            assert v in defined or v[0] == "VG", \
                f"{ins.pretty()} uses undefined {v}"
        if ins.target is not None:
            defined.add(ins.target)


class TestRawPlan:
    def test_fig2_raw_structure(self):
        plan = generate_raw_plan(FAN5, FIG2_ORDER)
        _well_formed(plan)
        ops = plan.count_ops()
        assert ops[ENU] == 5           # one per non-start vertex
        assert ops[DBQ] >= 3           # A1, A3, A5 at least
        assert ops[RES] == 1

    def test_all_patterns_all_orders_well_formed(self):
        import itertools
        for name in ("triangle", "square", "chordal-square", "house"):
            p = get_pattern(name)
            for order in itertools.permutations(range(p.n)):
                plan = generate_raw_plan(p, order)
                _well_formed(plan)

    def test_bad_order_rejected(self):
        with pytest.raises(ValueError):
            generate_raw_plan(FAN5, (0, 1))


class TestOpt1CSE:
    def test_fig2_cse_finds_a1a3(self):
        """Paper Example 3: {A1, A3} is eliminated first for the demo order."""
        plan = generate_raw_plan(FAN5, FIG2_ORDER)
        n = common_subexpression_elimination(plan)
        assert n >= 1
        _well_formed(plan)

    def test_cse_preserves_semantics_by_count(self):
        from repro.core.ref_engine import RefEngine
        from repro.graph.generate import erdos_renyi
        g = erdos_renyi(40, 140, seed=5)
        p = FAN5
        raw = generate_raw_plan(p, FIG2_ORDER)
        opt = generate_raw_plan(p, FIG2_ORDER)
        common_subexpression_elimination(opt)
        c_raw = RefEngine(raw, p, g)
        c_raw.run()
        c_opt = RefEngine(opt, p, g)
        c_opt.run()
        assert c_raw.counters.matches == c_opt.counters.matches
        # CSE must not increase INT executions
        assert c_opt.counters.int_ <= c_raw.counters.int_


class TestOpt2Reorder:
    def test_reorder_moves_int_before_enu(self):
        """Paper Example 4: hoisted instructions execute fewer times."""
        from repro.core.ref_engine import RefEngine
        from repro.graph.generate import erdos_renyi
        g = erdos_renyi(40, 140, seed=5)
        base = generate_raw_plan(FAN5, FIG2_ORDER)
        common_subexpression_elimination(base)
        re_plan = generate_raw_plan(FAN5, FIG2_ORDER)
        common_subexpression_elimination(re_plan)
        reorder_instructions(re_plan)
        _well_formed(re_plan)
        a = RefEngine(base, FAN5, g)
        a.run()
        b = RefEngine(re_plan, FAN5, g)
        b.run()
        assert a.counters.matches == b.counters.matches
        assert b.counters.computation_cost <= a.counters.computation_cost

    def test_reorder_keeps_dbq_enu_relative_order(self):
        plan = generate_raw_plan(FAN5, FIG2_ORDER)
        before = [i.target for i in plan.instrs if i.op in (DBQ, ENU)]
        reorder_instructions(plan)
        after = [i.target for i in plan.instrs if i.op in (DBQ, ENU)]
        assert [v for v in before if v[0] == "f"] == \
            [v for v in after if v[0] == "f"]


class TestOpt3Triangle:
    def test_fig2_trc_replaces_start_intersections(self):
        plan = generate_raw_plan(FAN5, FIG2_ORDER)
        common_subexpression_elimination(plan)
        reorder_instructions(plan)
        n = apply_triangle_cache(plan, FAN5)
        assert n >= 1                  # T7 / T6 in the paper's Fig. 2e
        assert any(i.op == TRC for i in plan.instrs)
        _well_formed(plan)

    def test_trc_cache_hits_on_real_graph(self):
        from repro.core.ref_engine import RefEngine
        from repro.graph.generate import powerlaw
        g = powerlaw(60, 4, seed=2)
        plan = generate_optimized_plan(FAN5, FIG2_ORDER)
        eng = RefEngine(plan, FAN5, g)
        eng.run()
        if eng.counters.trc > 0:
            assert eng.counters.trc_hits >= 0


class TestVCBC:
    @pytest.mark.parametrize("pname", ["square", "chordal-square",
                                       "clique4", "house"])
    def test_compressed_counts_match(self, pname):
        from repro.core.ref_engine import (RefEngine,
                                           enumerate_matches_brute)
        from repro.core.vcbc import count_code
        from repro.graph.generate import erdos_renyi
        p = get_pattern(pname)
        g = erdos_renyi(40, 160, seed=7)
        plan = generate_best_plan(p, g.stats(), vcbc=True)
        assert plan.vcbc and plan.core_k < p.n
        eng = RefEngine(plan, p, g, collect="codes")
        eng.run()
        total = sum(count_code(plan, p, c) for c in eng.codes)
        brute = len(enumerate_matches_brute(
            p, g, symmetry_breaking_constraints(p)))
        assert total == brute


class TestBestPlanSearch:
    def test_pruning_reduces_candidates(self):
        stats = GraphStats(1_000_000, 10_000_000)
        for pname in ("square", "clique4", "house", "fan5"):
            p = get_pattern(pname)
            sr = search_matching_orders(p, stats)
            assert sr.candidates, pname
            assert sr.orders_explored <= sr.orders_total

    def test_dual_pruning_keeps_canonical_order(self):
        p = get_pattern("square")       # u1~=u3, u2~=u4 (0-based 0~2, 1~3)
        stats = GraphStats(1_000_000, 10_000_000)
        sr = search_matching_orders(p, stats)
        for order in sr.candidates:
            assert order.index(0) < order.index(2)
            assert order.index(1) < order.index(3)

    def test_best_plan_minimizes_comm(self):
        stats = GraphStats(1_000_000, 10_000_000)
        p = get_pattern("chordal-square")
        best = generate_best_plan(p, stats)
        best_comm = estimate_communication_cost(p, best, stats)
        import itertools
        for order in itertools.permutations(range(p.n)):
            plan = generate_optimized_plan(p, order)
            assert best_comm <= estimate_communication_cost(
                p, plan, stats) * (1 + 1e-9)


class TestSymmetry:
    @pytest.mark.parametrize("pname", sorted(UNDIRECTED_PATTERNS))
    def test_unique_representative(self, pname):
        p = UNDIRECTED_PATTERNS[pname]
        cons = symmetry_breaking_constraints(p)
        assert check_unique_representative(p, cons)
