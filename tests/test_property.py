"""Hypothesis property tests on the system's invariants: random patterns
produce well-formed plans whose counts match brute force; symmetry breaking
yields exactly one representative; the cost model is permutation-consistent."""

import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis dev dep")
from hypothesis import HealthCheck, assume, given, settings
from hypothesis import strategies as st

from repro.core.engine_jax import enumerate_graph
from repro.core.pattern import Pattern
from repro.core.plangen import generate_best_plan, generate_optimized_plan
from repro.core.ref_engine import RefEngine, enumerate_matches_brute
from repro.core.symmetry import (check_unique_representative,
                                 symmetry_breaking_constraints)
from repro.graph.generate import erdos_renyi
from repro.graph.storage import Graph


def random_connected_pattern(draw, max_n=5):
    n = draw(st.integers(3, max_n))
    all_edges = list(itertools.combinations(range(n), 2))
    # spanning tree first (guarantees connectivity)
    perm = draw(st.permutations(list(range(n))))
    edges = {(min(perm[i], perm[i + 1]), max(perm[i], perm[i + 1]))
             for i in range(n - 1)}
    extra = draw(st.sets(st.sampled_from(all_edges), max_size=4))
    edges |= extra
    return Pattern(n, tuple(sorted(edges)), name=f"rand{n}")


pattern_strategy = st.builds(
    lambda seed: None, st.integers())  # placeholder replaced by composite


@st.composite
def patterns(draw):
    return random_connected_pattern(draw)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(patterns())
def test_symmetry_unique_representative_random(p):
    cons = symmetry_breaking_constraints(p)
    assert check_unique_representative(p, cons)


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(patterns(), st.integers(0, 1000))
def test_best_plan_counts_match_brute_random(p, seed):
    g = erdos_renyi(24, 70, seed=seed % 7)
    plan = generate_best_plan(p, g.stats())
    eng = RefEngine(plan, p, g)
    eng.run()
    brute = len(enumerate_matches_brute(
        p, g, symmetry_breaking_constraints(p)))
    assert eng.counters.matches == brute


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(patterns(), st.integers(0, 5))
def test_jax_engine_counts_match_random(p, gseed):
    g = erdos_renyi(24, 70, seed=gseed)
    plan = generate_best_plan(p, g.stats())
    brute = len(enumerate_matches_brute(
        p, g, symmetry_breaking_constraints(p)))
    res = enumerate_graph(plan, g, batch=16)
    assert res["count"] == brute


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(patterns())
def test_every_order_gives_same_count(p):
    """Plan semantics are order-invariant (the count is a graph property)."""
    g = erdos_renyi(18, 45, seed=3)
    counts = set()
    for order in list(itertools.permutations(range(p.n)))[:6]:
        plan = generate_optimized_plan(p, order)
        eng = RefEngine(plan, p, g)
        eng.run()
        counts.add(eng.counters.matches)
    assert len(counts) == 1


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(1, 60), st.integers(0, 99))
def test_graph_canonicalization_degree_order(n, m, seed):
    """After canonical relabeling, vertex id order extends degree order —
    the property that makes symmetry filters plain integer compares."""
    g = erdos_renyi(n, min(m, n * (n - 1) // 2), seed=seed)
    deg = g.deg
    assert all(deg[i] <= deg[i + 1] for i in range(g.n - 1))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=0, max_size=20),
       st.integers(1, 8))
def test_padded_adjacency_roundtrip(vals, lane):
    edges = [(v % 7, (v * 3 + 1) % 7) for v in vals if v % 7 != (v * 3 + 1) % 7]
    g = Graph.from_edges(7, edges, canonicalize=False)
    rows, deg = g.padded_adjacency(lane=lane)
    assert rows.shape[1] % lane == 0
    for v in range(7):
        real = [x for x in rows[v] if x < 7]
        assert real == sorted(int(w) for w in g.adj[v])
