"""S-BENU: incremental pattern graphs, plan generation (incl. the paper's
Fig. 6b reproduction), and continuous enumeration vs the snapshot-diff
oracle — plus Theorem 5 (no duplicates across incremental patterns)."""

import pytest

from repro.core.estimate import GraphStats
from repro.core.pattern import DIRECTED_PATTERNS, get_pattern
from repro.core.sbenu import (IncrementalPattern, SBenuRefEngine,
                              generate_best_sbenu_plans,
                              generate_sbenu_plan, incremental_patterns,
                              run_timestep, snapshot_diff_oracle)
from repro.graph.dynamic import SnapshotStore
from repro.graph.generate import edge_stream


def test_tau_mapping():
    p = get_pattern("dtoy")
    dps = incremental_patterns(p)
    assert len(dps) == p.m
    dp2 = dps[1]
    assert dp2.tau(1) == "either"
    assert dp2.tau(2) == "delta"
    assert dp2.tau(3) == "unaltered"


def test_fig6b_plan_reproduction():
    """The paper's Fig. 6b: ΔP_2 of the dtoy pattern with O: u1, u3, u2."""
    p = get_pattern("dtoy")
    dp = IncrementalPattern(p, 2)
    plan = generate_sbenu_plan(dp, (0, 2, 1))
    text = plan.pretty()
    # the eight instructions of Fig. 6b, in order
    assert "f1 := Init(start)" in text
    assert "ADO1 := GetAdj(f1,delta,out,*)" in text
    assert "op,f3 := Foreach" in text
    assert "AEO1 := GetAdj(f1,either,out,op)" in text
    assert "AUI3 := GetAdj(f3,unaltered,in,op)" in text
    assert "Intersect(AEO1, AUI3)" in text
    lines = text.splitlines()
    denu = next(i for i, l in enumerate(lines) if "op,f3" in l)
    aeo = next(i for i, l in enumerate(lines) if "AEO1" in l)
    assert denu < aeo                  # op-dependent DBQ after Delta-ENU


@pytest.mark.parametrize("pname", sorted(DIRECTED_PATTERNS))
def test_continuous_enumeration_vs_oracle(pname):
    p = DIRECTED_PATTERNS[pname]
    g0, batches = edge_stream(n=25, m_init=100, steps=3, batch=25, seed=11)
    store = SnapshotStore(g0)
    stats = GraphStats(25, 100, delta_edges=25)
    plans = generate_best_sbenu_plans(p, stats)
    assert len(plans) == p.m
    for batch in batches:
        want_p, want_m = snapshot_diff_oracle(p, store, batch)
        got_p, got_m, _ = run_timestep(p, plans, store, batch)
        assert got_p == want_p
        assert got_m == want_m


def test_theorem5_no_duplicates_across_plans():
    """Each match is produced by exactly one ΔP_i (engine-level check)."""
    p = get_pattern("q3'")
    g0, batches = edge_stream(n=20, m_init=80, steps=2, batch=20, seed=3)
    store = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(p, GraphStats(20, 80, delta_edges=20))
    for batch in batches:
        store.begin_step(batch)
        eng = SBenuRefEngine(plans, p, store)
        eng.run_timestep()
        assert len(eng.delta_plus) == len(set(eng.delta_plus))
        assert len(eng.delta_minus) == len(set(eng.delta_minus))
        store.end_step()


def test_task_splitting_sbenu():
    p = get_pattern("q1'")
    g0, batches = edge_stream(n=30, m_init=150, steps=1, batch=40, seed=5)
    store = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(p, GraphStats(30, 150,
                                                    delta_edges=40))
    want_p, want_m = snapshot_diff_oracle(p, store, batches[0])
    got_p, got_m, ctr = run_timestep(p, plans, store, batches[0], theta=3)
    assert got_p == want_p and got_m == want_m


def test_stricter_dual_condition():
    """q5' (DAG K4) has vertices that are SE undirected but not under typed
    containment — the incremental SE must be stricter or equal."""
    p = get_pattern("q5'")
    for dp in incremental_patterns(p):
        classes = dp.se_classes()
        for group in classes:
            for a in group:
                for b in group:
                    if a != b:
                        assert dp.syntactic_equivalent(a, b)


def test_two_form_storage_updates_only_touched():
    g0, batches = edge_stream(n=15, m_init=50, steps=1, batch=10, seed=9)
    store = SnapshotStore(g0)
    store.begin_step(batches[0])
    touched = set(store.delta_out) | set(store.delta_in)
    assert touched
    assert len(touched) < g0.n         # only a fraction of vertices change
