"""Vectorized S-BENU: the six-block device layout, the device-resident
dual-snapshot store, the JIT delta-frontier engine, and the padded-row
truncation guard."""

import warnings

import numpy as np
import pytest

from repro.core.estimate import GraphStats
from repro.core.pattern import get_pattern
from repro.core.sbenu import generate_best_sbenu_plans, snapshot_diff_oracle
from repro.graph.dynamic import DeviceSnapshotStore, SnapshotStore
from repro.graph.generate import edge_stream
from repro.graph.storage import DiGraph, Graph

# --------------------------------------------------------------------------
# storage: padded-row truncation is loud, never silent
# --------------------------------------------------------------------------


def test_padded_adjacency_truncation_raises():
    g = Graph.from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)])
    with pytest.raises(ValueError, match="truncated"):
        g.padded_adjacency(d_max=2, lane=1)


def test_padded_adjacency_truncation_clamp_warns():
    g = Graph.from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rows, deg = g.padded_adjacency(d_max=2, lane=1,
                                       on_overflow="clamp")
    assert len(w) == 1 and issubclass(w[0].category, RuntimeWarning)
    hub = int(np.argmax(deg))
    assert (rows[hub] != g.n).sum() == 2    # clamped to the padded width

    # a d_max under the max degree whose lane-rounded width still fits,
    # exact widths, and default widths all stay silent
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        g.padded_adjacency(d_max=2)          # lane=8 rounds up to 8 >= 4
        g.padded_adjacency(d_max=4, lane=1)
        g.padded_adjacency()
    assert not w


def test_digraph_padded_adjacency_directions():
    g = DiGraph.from_edges(4, [(0, 1), (0, 2), (3, 0)])
    out = g.padded_adjacency("out")
    inn = g.padded_adjacency("in")
    assert {int(x) for x in out[0] if x != 4} == {1, 2}
    assert {int(x) for x in inn[0] if x != 4} == {3}


# --------------------------------------------------------------------------
# storage: host-built six-block snapshot vs the dict-based get_adj
# --------------------------------------------------------------------------


def _row_set(rows, v, sentinel):
    return {int(x) for x in rows[v] if x != sentinel}


def test_device_snapshot_matches_get_adj():
    g0, batches = edge_stream(n=30, m_init=130, steps=1, batch=24, seed=7)
    store = SnapshotStore(g0)
    store.begin_step(batches[0])
    snap = store.device_snapshot()
    n = store.n
    blocks = {"out": (snap.prev_out, snap.cur_out, snap.delta_out,
                      snap.delta_out_sign),
              "in": (snap.prev_in, snap.cur_in, snap.delta_in,
                     snap.delta_in_sign)}
    for v in range(n):
        for di, (prev, cur, dv, ds) in blocks.items():
            assert _row_set(prev, v, n) == \
                set(store.get_adj(v, "either", di, "-"))
            assert _row_set(cur, v, n) == \
                set(store.get_adj(v, "either", di, "+"))
            plus = {int(x) for x, s in zip(dv[v], ds[v]) if s == 1}
            minus = {int(x) for x, s in zip(dv[v], ds[v]) if s == -1}
            assert plus == set(store.get_adj(v, "delta", di, "+"))
            assert minus == set(store.get_adj(v, "delta", di, "-"))
            assert _row_set(prev, v, n) - minus == \
                set(store.get_adj(v, "unaltered", di, "+"))
    # sentinel row is all holes / zero signs
    assert (snap.prev_out[n] == n).all()
    assert (snap.delta_in_sign[n] == 0).all()
    store.end_step()


def test_device_snapshot_store_tracks_host_across_steps():
    """The device-resident mirror must agree with a fresh host build on
    every step (its prev advances by on-device sort-compaction)."""
    g0, batches = edge_stream(n=30, m_init=140, steps=4, batch=25, seed=9)
    store = SnapshotStore(g0)
    ds = DeviceSnapshotStore.for_store(store)
    assert DeviceSnapshotStore.for_store(store) is ds   # mirror reuse
    for batch in batches:
        store.begin_step(batch)
        got = ds.step_snapshot()
        want = store.device_snapshot()
        n = store.n
        for v in range(n):
            for g_rows, w_rows in ((got.prev_out, want.prev_out),
                                   (got.cur_out, want.cur_out),
                                   (got.prev_in, want.prev_in),
                                   (got.cur_in, want.cur_in)):
                assert _row_set(np.asarray(g_rows), v, n) == \
                    _row_set(np.asarray(w_rows), v, n), v
        store.end_step()
    assert ds.rebuilds >= 1              # initial build only (no overflow)


def test_device_snapshot_store_invalidates_when_bypassed():
    """Steps run without the mirror (interpreter-only) must not leave it
    stale: the next use rebuilds from the host store."""
    g0, batches = edge_stream(n=20, m_init=80, steps=3, batch=15, seed=4)
    store = SnapshotStore(g0)
    ds = DeviceSnapshotStore.for_store(store)
    store.begin_step(batches[0])
    ds.step_snapshot()
    store.end_step()
    store.begin_step(batches[1])         # mirror not consulted this step
    store.end_step()
    store.begin_step(batches[2])
    got = ds.step_snapshot()
    want = store.device_snapshot()
    n = store.n
    for v in range(n):
        assert _row_set(np.asarray(got.cur_out), v, n) == \
            _row_set(np.asarray(want.cur_out), v, n)
    store.end_step()
    assert ds.rebuilds >= 2


# --------------------------------------------------------------------------
# engine: one compiled ΔP_i enumerator vs the snapshot diff
# --------------------------------------------------------------------------


def test_single_plan_enumerator_counts():
    import jax
    from repro.core.engine_sbenu_jax import (build_sbenu_enumerator,
                                             device_put_snapshot,
                                             plan_level_count)
    p = get_pattern("dtoy")
    g0, batches = edge_stream(n=20, m_init=80, steps=1, batch=15, seed=3)
    store = SnapshotStore(g0)
    plans = generate_best_sbenu_plans(p, GraphStats(20, 80, delta_edges=15))
    want_p, want_m = snapshot_diff_oracle(p, store, batches[0])
    store.begin_step(batches[0])
    snap = device_put_snapshot(store.device_snapshot())
    starts = np.asarray(store.start_vertices(), np.int32)
    valid = np.ones(starts.shape[0], bool)
    got_p, got_m = set(), set()
    for plan in plans:
        caps = [256] * plan_level_count(plan)
        run = jax.jit(build_sbenu_enumerator(plan, store.n, caps,
                                             collect_matches=True))
        res = run(snap, starts, valid)
        assert int(res.overflow) == 0
        mv = np.asarray(res.matches_valid)
        rows = np.asarray(res.matches)[mv]
        ops = np.asarray(res.match_ops)[mv]
        for row, o in zip(rows, ops):
            (got_p if o > 0 else got_m).add(tuple(int(x) for x in row))
    store.end_step()
    assert got_p == want_p
    assert got_m == want_m


def test_level_fanout_hints():
    from repro.core.engine_sbenu_jax import sbenu_level_fanouts
    stats = GraphStats(1000, 10000, delta_edges=100)
    # directed 4-cycle: the f3 level enumerates a single typed adjacency
    plans = generate_best_sbenu_plans(get_pattern("q2'"), stats)
    assert any(any(f) for f in map(sbenu_level_fanouts, plans))
    # directed triangle: every level intersects >= 2 adjacencies
    plans = generate_best_sbenu_plans(get_pattern("q1'"), stats)
    assert all(not any(f) for f in map(sbenu_level_fanouts, plans))


def test_sbenu_plans_reject_static_engine():
    """The static engine must keep refusing S-BENU plans (they route to
    engine_sbenu_jax instead)."""
    from repro.core.engine_jax import check_jit_supported
    plans = generate_best_sbenu_plans(get_pattern("q1'"),
                                      GraphStats(100, 500, delta_edges=10))
    with pytest.raises(NotImplementedError):
        check_jit_supported(plans[0])


# --------------------------------------------------------------------------
# storage: mesh-sharded six-block store vs a fresh host build (in-process
# single-device mesh — the 8-way layout is covered by the slow conformance
# matrix in test_conformance.py)
# --------------------------------------------------------------------------


def test_sharded_snapshot_store_matches_host_build():
    import jax
    from jax.sharding import Mesh
    from repro.graph.dynamic import ShardedDeviceSnapshotStore

    g0, batches = edge_stream(n=30, m_init=140, steps=3, batch=25, seed=9)
    store = SnapshotStore(g0)
    mesh = Mesh(np.array(jax.devices()), ("shard",))
    ds = ShardedDeviceSnapshotStore.for_store(store, mesh, hot=4)
    assert ShardedDeviceSnapshotStore.for_store(store, mesh, hot=4) is ds
    # a plain device mirror with "the same" layout params must NOT alias
    # the sharded one (their params tuples differ by construction)
    assert DeviceSnapshotStore.for_store(store) is not ds
    n = store.n
    for batch in batches:
        store.begin_step(batch)
        blocks, hot, spec = ds.step_sharded()
        want = store.device_snapshot()
        assert spec.n_shards * spec.rows_per_shard \
            == np.asarray(blocks["prev_out"]).shape[0]
        for name, wrows in (("prev_out", want.prev_out),
                            ("cur_out", want.cur_out),
                            ("prev_in", want.prev_in),
                            ("cur_in", want.cur_in)):
            got = np.asarray(blocks[name])
            for v in range(n):
                assert _row_set(got, v, n) == _row_set(wrows, v, n), \
                    (name, v)
            # hot slice = the top-id rows + the sentinel row, replicated
            hrows = np.asarray(hot[name])
            assert hrows.shape[0] == spec.hot + 1
            assert (hrows == got[n - spec.hot:n + 1]).all()
        # joint delta block round-trips values and signs
        dj = np.asarray(blocks["delta_joint_out"])
        dd = dj.shape[1] // 2
        for v in range(n):
            plus = {int(x) for x, s in zip(dj[v, :dd], dj[v, dd:])
                    if s == 1}
            minus = {int(x) for x, s in zip(dj[v, :dd], dj[v, dd:])
                     if s == -1}
            assert plus == set(store.get_adj(v, "delta", "out", "+")), v
            assert minus == set(store.get_adj(v, "delta", "out", "-")), v
        store.end_step()
    assert ds.rebuilds >= 1


def test_sbenu_snapshot_partition_specs_match_engine_layout():
    """The published specs (launch/shardings.py) must spell exactly the
    layout build_sbenu_dist_step's in_specs consume: value blocks
    row-partitioned, hot slices + starts as the engine expects."""
    from jax.sharding import PartitionSpec as P
    from repro.core.engine_sbenu_dist import BLOCK_ORDER
    from repro.launch.shardings import batch_specs, sbenu_snapshot_specs

    specs = sbenu_snapshot_specs("shard")
    assert len(specs) == 2 * len(BLOCK_ORDER) + 2
    for name in BLOCK_ORDER:
        assert specs[name] == P("shard", None), name
        assert specs[f"hot_{name}"] == P(None, None), name
    assert specs["starts"] == P("shard")
    assert specs["starts_valid"] == P("shard")
    # the dry-run kind routes to the same specs (flattened mesh axes)
    via_kind = batch_specs("benu", "sbenu_dist_enum", {}, False)
    assert via_kind["prev_out"] == P(("data", "model"), None)
