"""Fault tolerance: checkpoint/restart bit-exactness after an injected
failure, keep-K retention, elastic restore, and training-signal sanity."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipelines import LMStream
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.optimizer import AdamWConfig

CFG = LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
               d_head=16, d_ff=128, vocab=512, dtype=jnp.float32,
               remat=False)


def _setup(tmp):
    stream = LMStream(vocab=512, seq_len=32, global_batch=8)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, decay_steps=40)
    init_fn = lambda: init_params(jax.random.PRNGKey(0), CFG)
    lfn = lambda p, b: loss_fn(p, b, CFG)
    return stream, opt, init_fn, lfn


def test_restart_after_failure_is_bit_exact(tmp_path):
    stream, opt, init_fn, lfn = _setup(tmp_path)
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # interrupted run: crash at step 15, restart, finish
    ck = CheckpointManager(d1, keep=2)
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(lfn, init_fn, stream.batch, opt,
                     TrainLoopConfig(steps=25, ckpt_every=5, log_every=5,
                                     fail_at_step=15), ckpt=ck)
    h1 = run_training(lfn, init_fn, stream.batch, opt,
                      TrainLoopConfig(steps=25, ckpt_every=5, log_every=5),
                      ckpt=ck)
    # uninterrupted run
    ck2 = CheckpointManager(d2, keep=2)
    h2 = run_training(lfn, init_fn, stream.batch, opt,
                      TrainLoopConfig(steps=25, ckpt_every=5, log_every=5),
                      ckpt=ck2)
    assert h1["loss"][-1] == pytest.approx(h2["loss"][-1], abs=0.0)
    # final params identical leaf-for-leaf
    p1 = h1["final_state"]["params"]
    p2 = h2["final_state"]["params"]
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_retention(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": np.arange(4.0)}
    for step in (1, 2, 3, 4, 5):
        ck.save(step, state)
    assert ck.list_steps() == [4, 5]


def test_restore_shape_mismatch_rejected(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save(1, {"w": np.zeros((4, 4), np.float32)})
    with pytest.raises(ValueError, match="shape mismatch"):
        ck.restore(1, {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)})


def test_atomicity_no_partial_checkpoint(tmp_path):
    """A tmp dir left over from a crash is never listed as a checkpoint."""
    ck = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), ".tmp-7"))
    assert ck.list_steps() == []
    ck.save(7, {"w": np.zeros(3)})
    assert ck.list_steps() == [7]


def test_training_reduces_loss(tmp_path):
    stream, opt, init_fn, lfn = _setup(tmp_path)
    h = run_training(lfn, init_fn, stream.batch, opt,
                     TrainLoopConfig(steps=40, ckpt_every=1000,
                                     log_every=10))
    assert h["loss"][-1] < h["loss"][0] * 0.8


def test_elastic_restore_to_device(tmp_path):
    """Checkpoints are logical: restore re-shards to whatever is alive
    (here: explicit device_put shardings on the single local device)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ck = CheckpointManager(str(tmp_path))
    params = init_params(jax.random.PRNGKey(0), CFG)
    ck.save(3, {"params": params})
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shardings = jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), params)
    template = {"params": jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), CFG))}
    restored = ck.restore(3, template,
                          shardings={"params": shardings})
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
