#!/usr/bin/env python
"""Offline link checker for the repo's markdown docs.

    python tools/check_links.py README.md docs/*.md

Verifies that every relative markdown link / image target resolves to a
file or directory in the repo (anchors are stripped; external schemes —
http(s), mailto — are skipped: CI must not depend on the network). Exits
non-zero listing every broken link.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP = ("http://", "https://", "mailto:", "#")


def check(path: Path) -> list:
    broken = []
    text = path.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
        if in_code:
            continue
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                broken.append((path, lineno, target))
    return broken


def main(argv) -> int:
    files = [Path(a) for a in argv]
    if not files:
        print("usage: check_links.py FILE.md [FILE.md ...]")
        return 2
    broken = []
    for f in files:
        if not f.exists():
            broken.append((f, 0, "<file missing>"))
            continue
        broken.extend(check(f))
    if broken:
        for path, lineno, target in broken:
            print(f"BROKEN {path}:{lineno}: {target}")
        return 1
    print(f"all links OK in {len(files)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
